"""Flow-level simulator vs the paper's published numbers (§5)."""
import numpy as np
import pytest

from repro.configs.opera_paper import OPERA_648
from repro.core.expander import random_regular_expander
from repro.netsim.capacity import (
    fig12_model,
    crossover_alpha,
    summary_648,
)
from repro.netsim.flows import percentile_fct, simulate
from repro.netsim.fluid import (
    simulate_clos_bulk,
    simulate_expander_bulk,
    simulate_rotor_bulk,
)
from repro.netsim.workloads import (
    byte_fraction_below,
    demand_all_to_all,
    demand_hotrack,
    demand_permutation,
    demand_skew,
    mean_flow_size,
    sample_flow_sizes,
)


class TestWorkloads:
    def test_datamining_bulk_byte_fraction(self):
        # §5.1: ~4 % of Datamining bytes are below the 15 MB cutoff
        f = byte_fraction_below("datamining", 15e6)
        assert 0.02 <= f <= 0.07

    def test_websearch_all_below_cutoff(self):
        # §5.3: Websearch is entirely below the bulk cutoff
        assert byte_fraction_below("websearch", 15e6) >= 0.95

    def test_sampler_within_support(self):
        s = sample_flow_sizes("hadoop", 10_000, np.random.default_rng(0))
        assert s.min() >= 100 and s.max() <= 100e6

    def test_demands(self):
        d = demand_all_to_all(8, 4, 100.0)
        assert d[0, 0] == 0 and d[0, 1] == 4 * 4 * 100.0
        assert demand_hotrack(8, 4, 10.0).sum() == 40.0
        p = demand_permutation(8, 4, 10.0)
        assert (p.sum(1) > 0).all() and np.diag(p).sum() == 0
        assert demand_skew(10, 4, 10.0, 0.2).sum() > 0


class TestPermutationDerangement:
    """Regression for the self-map repair: the old rotate-fix computed
    the self-mapped indices once, so adjacent self-maps were swapped
    twice and reverted to identity — placing intra-rack bytes on the
    fabric diagonal."""

    @pytest.mark.parametrize("num_racks", [3, 4, 5, 8, 16, 37])
    def test_zero_diagonal_and_valid_permutation_many_seeds(self, num_racks):
        for seed in range(300):
            d = demand_permutation(num_racks, 4, 10.0, seed=seed)
            assert np.diag(d).sum() == 0.0, seed
            dests = d.argmax(1)
            assert (d.sum(1) > 0).all(), seed
            assert sorted(dests) == list(range(num_racks)), seed


class TestByteFractionClosedForm:
    """The Monte-Carlo integral was replaced by the exact integral over
    the piecewise log-linear CDF; the (fixed) sampler must agree."""

    @pytest.mark.parametrize("name", ["websearch", "datamining", "hadoop"])
    def test_matches_sampler_monte_carlo(self, name):
        rng = np.random.default_rng(0)
        s = sample_flow_sizes(name, 300_000, rng)
        for cutoff in (100e3, 1e6, 15e6):
            mc = float(s[s < cutoff].sum() / s.sum())
            assert abs(byte_fraction_below(name, cutoff) - mc) < 0.015

    def test_monotone_and_bounded(self):
        prev = 0.0
        for cutoff in (50, 1e3, 1e6, 15e6, 1e9, 1e12):
            f = byte_fraction_below("datamining", cutoff)
            assert prev - 1e-12 <= f <= 1.0
            prev = f
        assert byte_fraction_below("datamining", 1e12) == 1.0
        assert byte_fraction_below("datamining", 50) == 0.0

    def test_sampler_mean_matches_closed_form(self):
        rng = np.random.default_rng(1)
        s = sample_flow_sizes("websearch", 400_000, rng)
        assert abs(s.mean() / mean_flow_size("websearch") - 1.0) < 0.02

    def test_sampler_atom_at_first_point(self):
        # P[S = s_first] must equal the CDF's first probability
        rng = np.random.default_rng(2)
        s = sample_flow_sizes("websearch", 200_000, rng)
        atom = float(np.isclose(s, 6e3, rtol=1e-9).mean())
        assert abs(atom - 0.15) < 0.01


class TestP99SmallClasses:
    """`percentile_fct` small-n paths: no NaN may leak into benchmark
    JSON or `summarize` means."""

    def test_empty_class_sentinel(self):
        sel = np.zeros(4, bool)
        ok = np.ones(4, bool)
        assert percentile_fct(np.ones(4), sel, ok) == 0.0

    def test_few_finished_no_unfinished_is_finite(self):
        fct = np.array([1.0, 2.0, 3.0, 100.0])
        sel = np.array([True, True, False, False])
        ok = np.ones(4, bool)
        p = percentile_fct(fct, sel, ok)
        assert np.isfinite(p) and 1.0 <= p <= 2.0

    def test_unfinished_small_class_is_inf(self):
        fct = np.array([1.0, np.inf, np.inf])
        sel = np.ones(3, bool)
        ok = np.array([True, False, False])
        assert percentile_fct(fct, sel, ok) == float("inf")

    def test_no_nan_in_simulated_result(self):
        # tiny scenario: the >=15 MB class has <5 flows at this scale
        r = simulate("opera", "websearch", 0.05, num_hosts=16,
                     horizon_s=0.1, dt_s=5e-4, tail_s=0.1, seed=0)
        for f in ("fct_p99_ms_small", "fct_p99_ms_mid", "fct_p99_ms_large",
                  "fct_mean_ms", "backlog_frac"):
            assert not np.isnan(getattr(r, f)), f


class TestShuffleFig8:
    """100 KB all-to-all (Fig. 8): Opera ~60 ms vs ~220+ ms static."""

    def test_opera_60ms_and_taxfree(self):
        d = demand_all_to_all(108, 6, 100e3)
        r = simulate_rotor_bulk(OPERA_648, d, vlb=False, max_cycles=40)
        assert 50 <= r.fct_99_ms <= 85          # paper: 60 ms
        assert r.bandwidth_tax < 0.01           # direct paths: no tax

    def test_static_networks_3x_slower(self):
        d = demand_all_to_all(108, 6, 100e3)
        opera = simulate_rotor_bulk(OPERA_648, d, vlb=False, max_cycles=40)
        clos = simulate_clos_bulk(648, d, 10.0, 3.0)
        adj = random_regular_expander(130, 7, seed=1)
        exp = simulate_expander_bulk(
            adj, demand_all_to_all(130, 5, 100e3), 10.0, dt_us=2000.0
        )
        assert clos.fct_99_ms / opera.fct_99_ms > 1.8
        assert exp.fct_99_ms / opera.fct_99_ms > 1.8
        assert exp.bandwidth_tax > 1.0          # multi-hop tax on every byte


class TestCapacityModel:
    def test_summary_matches_paper(self):
        s = summary_648()
        assert 0.08 <= s["opera_latency_load"] <= 0.13   # §5.3: ~10 %
        assert 0.22 <= s["expander_load"] <= 0.30        # ~25 %
        assert 0.55 <= s["capacity_ratio"] <= 0.65       # "60 % of capacity"

    def test_fig12_shuffle_2x_even_at_alpha2(self):
        r = fig12_model(2.0, "shuffle")
        assert r["opera"] / max(r["expander"], r["clos"]) >= 1.6

    def test_fig12_crossover_near_paper(self):
        # paper: statics win for alpha > ~1.8 on permutation/skew
        a = crossover_alpha("permutation")
        assert 1.3 <= a <= 2.6

    def test_fig12_hotrack_comparable(self):
        r = fig12_model(1.3, "hotrack")
        assert r["opera"] >= 0.55 * r["expander"]


class TestFlowSim:
    def test_opera_datamining_carries_more_load_than_static(self):
        opera = simulate("opera", "datamining", 0.30, horizon_s=1.6, seed=1)
        expander = simulate("expander", "datamining", 0.30, horizon_s=1.6, seed=1)
        assert opera.backlog_frac < expander.backlog_frac

    def test_websearch_opera_admits_10pct(self):
        r = simulate("opera", "websearch", 0.08, horizon_s=0.8, seed=1)
        assert r.admitted
        r = simulate("opera", "websearch", 0.20, horizon_s=0.8, seed=1)
        assert not r.admitted                    # §5.3: saturates ~10 %

    def test_rotornet_low_latency_is_msscale(self):
        # Fig. 7c: non-hybrid RotorNet short-flow FCT ~ cycle time (ms),
        # 100-1000x worse than Opera's expander path (~us-scale baseline)
        rn = simulate("rotornet", "datamining", 0.05, horizon_s=0.8, seed=1)
        op = simulate("opera", "datamining", 0.05, horizon_s=0.8, seed=1)
        assert rn.fct_p99_ms_small > 20 * op.fct_p99_ms_small
