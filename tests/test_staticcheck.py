"""repro.staticcheck: invariant verifier + jaxpr/AST analyzer tests.

Each invariant rule is proven live by a seeded corrupted-topology
fixture that must fail it; the clean repo (and clean design points)
must pass everything — this is the tier-1 wiring of the analyzer.
"""
import dataclasses
import os
import textwrap

import numpy as np
import pytest

from repro.core.topology import build_opera_topology
from repro.staticcheck.findings import Finding, Report, allowed_lines
from repro.staticcheck.invariants import (
    InvariantConfig,
    check_cycle_coverage,
    check_expander,
    check_fault_masks,
    check_matching_union,
    check_reconfiguration,
    check_static_fabric,
    verify_topology,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def topo():
    # small Appendix-B-style point: k=8 -> u=4, 16 racks, ungrouped
    return build_opera_topology(16, 4, seed=0, groups=1)


@pytest.fixture(scope="module")
def tensor(topo):
    return topo.matching_tensor()


def rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# Layer 1: invariants — clean topologies pass
# ---------------------------------------------------------------------------


class TestInvariantsPass:
    def test_all_rules_clean_on_good_topology(self, topo, tensor):
        assert verify_topology(topo, tensor) == []

    @pytest.mark.parametrize("n,u,g", [(12, 3, 1), (16, 4, 2), (24, 4, 1)])
    def test_matching_cover_reconf_across_designs(self, n, u, g):
        t = build_opera_topology(n, u, seed=1, groups=g)
        ten = t.matching_tensor()
        assert check_matching_union(t, ten) == []
        assert check_cycle_coverage(t, ten) == []
        assert check_reconfiguration(t, ten) == []

    def test_static_fabrics_clean(self):
        from repro.core.expander import random_regular_expander
        from repro.core.topology import expander_union

        assert check_static_fabric(expander_union(26, 5, seed=0),
                                   "expander_union") == []
        assert check_static_fabric(random_regular_expander(26, 5, seed=0),
                                   "rre") == []


# ---------------------------------------------------------------------------
# Layer 1: invariants — seeded corrupted fixtures fail each rule
# ---------------------------------------------------------------------------


class TestCorruptedTopologies:
    def test_match_fails_on_self_map(self, topo, tensor):
        bad = tensor.copy()
        bad[0, 3, 3] = 1.0            # self-map in slice 0
        assert "SC-INV-MATCH" in rules(check_matching_union(topo, bad))

    def test_match_fails_on_asymmetric_edge(self, topo, tensor):
        bad = tensor.copy()
        n = topo.num_racks
        off_zero = np.argwhere((bad[0] == 0) & ~np.eye(n, dtype=bool))
        i, j = off_zero[0]
        bad[0, i, j] = 1.0            # directed edge with no reverse
        assert "SC-INV-MATCH" in rules(check_matching_union(topo, bad))

    def test_match_fails_on_non_involution_matching(self, topo):
        # replace one switch matching by a 3-cycle permutation (a valid
        # permutation but NOT an involution -> not a matching)
        n = topo.num_racks
        cyc = np.roll(np.arange(n), 1).astype(np.int64)
        sm = [list(ms) for ms in topo.switch_matchings]
        sm[0][0] = cyc
        bad = dataclasses.replace(
            topo, switch_matchings=tuple(tuple(ms) for ms in sm))
        assert "SC-INV-MATCH" in rules(check_matching_union(bad))

    def test_cover_fails_on_dropped_pair(self, topo, tensor):
        bad = tensor.copy()
        bad[:, 0, 1] = 0.0            # pair (0, 1) never gets a circuit
        bad[:, 1, 0] = 0.0
        found = check_cycle_coverage(topo, bad)
        assert "SC-INV-COVER" in rules(found)
        assert any("no direct circuit" in f.message for f in found)

    def test_cover_fails_on_duplicated_slice_coverage(self, topo, tensor):
        bad = tensor.copy()
        bad[1] = bad[0]               # double-covers slice 0's pairs
        assert "SC-INV-COVER" in rules(check_cycle_coverage(topo, bad))

    def test_expander_fails_on_disconnected_slice(self, topo, tensor):
        n = topo.num_racks
        half = n // 2
        blk = np.zeros((n, n), np.float32)
        blk[:half, :half] = 1.0       # two cliques, no bridge
        blk[half:, half:] = 1.0
        np.fill_diagonal(blk, 0.0)
        bad = tensor.copy()
        bad[2] = blk
        found = check_expander(topo, bad)
        assert "SC-INV-EXPAND" in rules(found)
        assert any("disconnected" in f.message for f in found)

    def test_expander_fails_on_low_spectral_gap(self, topo, tensor):
        # barbell: two cliques joined by one edge — connected, min degree
        # 7, but a near-zero spectral gap (the classic bad expander)
        n = topo.num_racks
        half = n // 2
        barbell = np.zeros((n, n), np.float32)
        barbell[:half, :half] = 1.0
        barbell[half:, half:] = 1.0
        np.fill_diagonal(barbell, 0.0)
        barbell[0, half] = barbell[half, 0] = 1.0
        bad = tensor.copy()
        bad[1] = barbell
        found = check_expander(topo, bad)
        assert "SC-INV-EXPAND" in rules(found)
        assert any("spectral gap" in f.message for f in found)

    def test_reconf_fails_on_wholesale_slice_swap(self, topo, tensor):
        # relabel one slice by a seeded random permutation: nearly every
        # live link moves -> way beyond the 2*groups*N piecewise bound
        rng = np.random.default_rng(7)
        perm = rng.permutation(topo.num_racks)
        bad = tensor.copy()
        bad[1] = bad[1][perm][:, perm]
        assert "SC-INV-RECONF" in rules(check_reconfiguration(topo, bad))

    def test_fabric_fails_on_disconnected(self):
        adj = np.zeros((8, 8), bool)
        adj[:4, :4] = ~np.eye(4, dtype=bool)
        adj[4:, 4:] = ~np.eye(4, dtype=bool)
        assert "SC-INV-FABRIC" in rules(check_static_fabric(adj, "split"))


# ---------------------------------------------------------------------------
# Layer 1: SC-INV-FAULT — fault-masked tensors + switch-fault budget
# ---------------------------------------------------------------------------


class TestFaultInvariant:
    def test_clean_on_budget_selected_realization(self):
        # n12-u6 converges instantly in the generate-and-test loop and
        # genuinely survives any 2 switch failures in every slice
        ft = build_opera_topology(12, 6, seed=0, switch_fault_tolerance=2)
        assert check_fault_masks(ft, budget=2) == []

    def test_fires_on_unselected_realization(self):
        # plain 16-rack u=4 seed-0 build: single-switch failures leave
        # 2-matching slices that fall apart into disjoint cycles
        topo = build_opera_topology(16, 4, seed=0)
        found = check_fault_masks(topo, budget=1)
        assert "SC-INV-FAULT" in rules(found)
        assert any("disconnects under switch failures" in f.message
                   for f in found)

    def test_fires_on_asymmetric_masked_tensor(self, topo, tensor):
        bad = tensor.copy()
        n = topo.num_racks
        off_zero = np.argwhere((bad[0] == 0) & ~np.eye(n, dtype=bool))
        i, j = off_zero[0]
        bad[0, i, j] = 1.0            # survives masking -> masked asym
        found = check_fault_masks(topo, tensor=bad)
        assert any("not symmetric" in f.message for f in found)

    def test_fires_when_draw_removes_nothing(self, topo):
        # an all-zero tensor has no capacity for the link draw to remove
        zero = np.zeros_like(topo.matching_tensor())
        found = check_fault_masks(topo, tensor=zero)
        assert any("removed no" in f.message for f in found)


# ---------------------------------------------------------------------------
# Layer 2b: AST rules
# ---------------------------------------------------------------------------


def _scan_src(tmp_path, rel, source):
    """Write `source` at tmp_path/rel and run the per-file AST rules."""
    import ast as ast_mod

    from repro.staticcheck.ast_rules import check_compat_policy, check_engine_f64

    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    tree = ast_mod.parse(path.read_text())
    root = str(tmp_path)
    return (check_compat_policy(root, str(path), tree, path.read_text())
            + check_engine_f64(root, str(path), tree, path.read_text()))


class TestAstRules:
    def test_direct_experimental_shard_map_flagged(self, tmp_path):
        found = _scan_src(tmp_path, "src/repro/x.py",
                          "from jax.experimental.shard_map import shard_map\n")
        assert rules(found) == {"SC-AST-COMPAT"}

    def test_jax_attribute_surface_flagged(self, tmp_path):
        found = _scan_src(tmp_path, "src/repro/y.py", """\
            import jax
            mesh = jax.make_mesh((1,), ("d",))
            jax.set_mesh(mesh)
            f = jax.shard_map(lambda x: x, mesh=mesh, in_specs=None,
                              out_specs=None)
            g = jax.experimental.shard_map.shard_map
            """)
        found_rules = [f.rule for f in found]
        assert found_rules.count("SC-AST-COMPAT") == 4

    def test_compat_module_exempt(self, tmp_path):
        found = _scan_src(tmp_path, "src/repro/compat.py", """\
            import jax
            def shard_map(f, **kw):
                return jax.shard_map(f, **kw)
            """)
        assert found == []

    def test_shadowing_compat_surface_flagged(self, tmp_path):
        found = _scan_src(tmp_path, "src/repro/launch/m.py", """\
            from repro.compat import make_mesh as _mm
            def make_mesh(shape, axes):
                return _mm(shape, axes)
            set_mesh = None
            """)
        assert [f.rule for f in found] == ["SC-AST-SHADOW", "SC-AST-SHADOW"]

    def test_engine_f64_requires_directive(self, tmp_path):
        src = """\
            import numpy as np
            a = np.zeros(3, np.float64)
            b = np.zeros(3, np.float64)  # staticcheck: ok SC-AST-F64 (host staging)
            # staticcheck: ok SC-AST-F64 (host staging)
            c = np.zeros(3, np.float64)
            """
        found = _scan_src(tmp_path, "src/repro/netsim/foo_jax.py", src)
        assert [f.rule for f in found] == ["SC-AST-F64"]
        assert found[0].line == 2
        # same file outside an engine path: rule does not apply
        assert _scan_src(tmp_path, "src/repro/netsim/foo.py", src) == []

    def test_directive_parser(self):
        src = "x = 1\n# staticcheck: ok SC-AST-F64, SC-JAX-F64 (why)\ny = 2\n"
        ok = allowed_lines(src, "SC-AST-F64")
        assert ok == {2, 3}
        assert allowed_lines(src, "SC-INV-MATCH") == set()

    def test_kernel_trio_missing_ref_flagged(self, tmp_path):
        from repro.staticcheck.ast_rules import check_kernel_trios

        pkg = tmp_path / "src" / "repro" / "kernels" / "newkern"
        pkg.mkdir(parents=True)
        (pkg / "kernel.py").write_text("")
        (pkg / "ops.py").write_text("")
        found = check_kernel_trios(str(tmp_path))
        assert rules(found) == {"SC-AST-TRIO"}
        assert "ref.py" in found[0].message

    def test_lockstep_pair_rule(self):
        from repro.staticcheck.ast_rules import check_lockstep

        lone = check_lockstep(["src/repro/netsim/fluid_jax.py"])
        assert rules(lone) == {"SC-AST-LOCKSTEP"}
        both = check_lockstep(["src/repro/netsim/fluid.py",
                               "src/repro/netsim/fluid_jax.py",
                               "src/repro/netsim/flows.py",
                               "src/repro/netsim/flows_jax.py"])
        assert both == []
        unrelated = check_lockstep(["ROADMAP.md", "src/repro/compat.py"])
        assert unrelated == []

    def test_lockstep_faults_coupling(self):
        """A faults.py diff is a failure-semantics diff: every engine
        pair must be touched (both members), else the pair is flagged."""
        from repro.staticcheck.ast_rules import check_lockstep

        alone = check_lockstep(["src/repro/netsim/faults.py"])
        assert len(alone) == 2          # one finding per untouched pair
        assert rules(alone) == {"SC-AST-LOCKSTEP"}
        assert all("failure semantics" in f.message for f in alone)
        half = check_lockstep(["src/repro/netsim/faults.py",
                               "src/repro/netsim/fluid.py"])
        # fluid pair: half-touched (base rule); flows pair: untouched
        assert len(half) == 2
        full = check_lockstep(["src/repro/netsim/faults.py",
                               "src/repro/netsim/fluid.py",
                               "src/repro/netsim/fluid_jax.py",
                               "src/repro/netsim/flows.py",
                               "src/repro/netsim/flows_jax.py"])
        assert full == []

    def test_whole_tree_is_clean(self):
        """Tier-1 gate: the repo itself passes every AST policy rule."""
        from repro.staticcheck.ast_rules import scan_tree

        found = scan_tree(REPO_ROOT, lockstep=False)
        assert found == [], "\n".join(str(f) for f in found)


# ---------------------------------------------------------------------------
# Layer 2a: jaxpr rules
# ---------------------------------------------------------------------------


class TestJaxprRules:
    @pytest.fixture(scope="class")
    def entries(self):
        from repro.staticcheck.jaxpr_rules import trace_entrypoints

        entries, trace_findings = trace_entrypoints()
        assert trace_findings == []
        return entries

    def test_all_entrypoints_trace(self, entries):
        names = {e.name for e in entries}
        assert len(names) == 13
        assert any("fluid_jax" in n for n in names)
        assert "netsim.fluid_jax._run_batch_faulted" in names
        assert "netsim.flows_jax._run_batch_faulted" in names
        assert "netsim.flows_jax._run_tiled_chunk" in names
        assert "netsim.flows_jax._run_tiled_chunk_faulted" in names
        assert "netsim.fluid_jax._sparse_slice_step" in names
        assert "netsim.fluid_jax._sparse_slice_step_faulted" in names
        assert "kernels.rotor_slice.ops.rotor_slice_step" in names
        assert any("flash_attention" in n for n in names)

    def test_engines_have_no_f64_or_callbacks(self, entries):
        from repro.staticcheck.jaxpr_rules import check_callbacks, check_float64

        assert check_float64(entries) == []
        assert check_callbacks(entries) == []

    def test_f64_leak_is_caught(self):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.experimental import enable_x64

        from repro.staticcheck.jaxpr_rules import TracedEntry, check_float64

        def leaky(x):
            return x * jnp.asarray(np.float64(2.0))  # f64 constant promotes

        with enable_x64():
            closed = jax.make_jaxpr(leaky)(
                jax.ShapeDtypeStruct((4,), jnp.float32))
        found = check_float64([TracedEntry("leaky", "x.py", 1, closed)])
        assert rules(found) == {"SC-JAX-F64"}

    def test_host_callback_is_caught(self):
        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.staticcheck.jaxpr_rules import TracedEntry, check_callbacks

        def chatty(x):
            y = jax.pure_callback(
                lambda a: np.asarray(a), jax.ShapeDtypeStruct((4,), jnp.float32), x)
            return jax.lax.scan(lambda c, _: (c + y, None), x, None, length=3)[0]

        closed = jax.make_jaxpr(chatty)(jax.ShapeDtypeStruct((4,), jnp.float32))
        found = check_callbacks([TracedEntry("chatty", "x.py", 1, closed)])
        assert rules(found) == {"SC-JAX-CALLBACK"}


class TestRecompilePinning:
    def test_sweep_grid_compiles_once_per_design_point(self):
        """Regression pin (ROADMAP sweep runner): a (k, num_racks, groups)
        x workload x load x seed grid must produce exactly one fresh
        `fluid_jax._run_batch` lowering per design point, and re-running
        the same grid with different loads/seeds must reuse them all."""
        from repro.staticcheck.jaxpr_rules import count_sweep_lowerings

        designs = ((4, 14, 1), (4, 18, 1))   # shapes unique to this test
        new, num_designs, findings = count_sweep_lowerings(
            designs=designs, loads=(0.1, 0.25), seeds=(0, 1), max_cycles=8)
        assert findings == []
        assert new == num_designs == len(designs)
        # same design shapes, fresh loads/seeds: zero new lowerings
        new2, _, findings2 = count_sweep_lowerings(
            designs=designs, loads=(0.15, 0.3), seeds=(2, 3), max_cycles=8)
        assert findings2 == []
        assert new2 == 0

    def test_fault_draws_share_one_lowering(self):
        """Failure timelines are data: distinct draws through one design
        point must add at most one `_run_batch_faulted` lowering, and a
        re-run with fresh draws must add none."""
        from repro.staticcheck.jaxpr_rules import count_fault_lowerings

        new, findings = count_fault_lowerings(num_draws=3, max_cycles=5)
        assert findings == []
        assert new <= 1
        new2, findings2 = count_fault_lowerings(num_draws=2, max_cycles=5)
        assert findings2 == []
        assert new2 == 0

    def test_sparse_demand_draws_share_one_lowering(self):
        """Sparse engine: distinct demand draws through one design point
        must add at most one `_sparse_slice_step` lowering (slice index
        tensors are data, not static), and a re-run must add none."""
        from repro.staticcheck.jaxpr_rules import count_sparse_lowerings

        new, findings = count_sparse_lowerings(num_cycles=3, num_demands=2)
        assert findings == []
        assert new <= 1
        new2, findings2 = count_sparse_lowerings(num_cycles=3, num_demands=2)
        assert findings2 == []
        assert new2 == 0

    def test_tiled_flow_grid_shares_one_lowering(self):
        """Tiled flow engine: chunk shapes are (batch, window, tile)
        geometry only — loads and seeds are data.  A load x seed grid
        must add at most one `_run_tiled_chunk` lowering across a cold
        run plus a warm re-run, and a further re-run must add none."""
        from repro.staticcheck.jaxpr_rules import count_tiled_lowerings

        new, findings = count_tiled_lowerings(loads=(0.05, 0.2),
                                              seeds=(0, 1))
        assert findings == []
        assert new <= 1
        new2, findings2 = count_tiled_lowerings(loads=(0.1, 0.15),
                                                seeds=(2, 3))
        assert findings2 == []
        assert new2 == 0


# ---------------------------------------------------------------------------
# Report plumbing + CLI smoke
# ---------------------------------------------------------------------------


class TestReport:
    def test_report_json_roundtrip(self, tmp_path):
        import json

        rep = Report()
        rep.extend([Finding("SC-INV-COVER", "boom", path="cycle-union"),
                    Finding("SC-AST-LOCKSTEP", "warn", path="a.py",
                            severity="warning")], "unit")
        assert not rep.ok
        assert rep.by_rule() == {"SC-INV-COVER": 1, "SC-AST-LOCKSTEP": 1}
        p = tmp_path / "report.json"
        rep.to_json(str(p))
        data = json.loads(p.read_text())
        assert data["num_errors"] == 1 and data["ok"] is False
        assert data["findings"][0]["rule"] == "SC-INV-COVER"

    def test_cli_small_design_exits_zero(self, tmp_path, capsys):
        from repro.staticcheck.cli import main

        out = tmp_path / "sc.json"
        rc = main(["--layers", "invariants,ast", "--designs", "k8-n16-g1",
                   "--json", str(out), "--root", REPO_ROOT, "-q"])
        assert rc == 0
        assert out.exists()
