"""Fault injection subsystem: determinism, lockstep parity, dispatch.

Covers the ISSUE-8 robustness contract:

* seeded `FailureSchedule` draws are reproducible (two-run determinism
  regression) and sample only the topology's *realized* uplinks;
* `FailureSchedule.empty()` is *bit*-identical to the failure-free
  engine paths (the public APIs dispatch event-less schedules to the
  original programs);
* the faulted numpy oracle and the faulted JAX lowering agree per-step
  for link/ToR/switch schedules, including the detection-lag blackhole
  window, for both engine pairs (fluid and flow-level);
* graceful degradation: blackholing only happens during the hello lag,
  demand is conserved (lost bytes re-queue), ToR-frozen flows retry
  after recovery, and the dynamic masks agree with the static
  `routing.slice_adjacency` view of the same draw.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.routing import slice_adjacency
from repro.core.schedule import cycle_timing, slice_capacity_bytes
from repro.core.topology import build_opera_topology
from repro.netsim import flows
from repro.netsim.faults import (
    NEVER,
    FailureEvent,
    FailureSchedule,
    apply_flow_faults,
    compile_fault_masks,
    live_uplinks,
    masked_tensor,
    step_masks,
    switch_id_tensor,
)
from repro.netsim.flows import build_scenario, finalize
from repro.netsim.flows_jax import simulate_flows_batch
from repro.netsim.fluid import simulate_rotor_bulk
from repro.netsim.fluid_jax import simulate_rotor_bulk_batch
from repro.netsim.sweep import DesignPoint

S_TINY = 8 * 1  # num_slices of the tiny design (8 racks, u=2 -> 8 slices)


@pytest.fixture(scope="module")
def topo():
    return build_opera_topology(8, 2, seed=0)


@pytest.fixture(scope="module")
def cfg():
    return DesignPoint(k=4, num_racks=8).to_config()


@pytest.fixture(scope="module")
def demand(cfg):
    cap = slice_capacity_bytes(cfg, cycle_timing(cfg))
    d = np.full((cfg.num_racks, cfg.num_racks), 1.5 * cap)
    np.fill_diagonal(d, 0.0)
    return d


def _draws(topo):
    S = topo.num_slices
    kw = dict(onset_step=S, detect_lag=3)
    return [
        ("links", FailureSchedule.draw(topo, seed=5, link_frac=0.15, **kw)),
        ("tors", FailureSchedule.draw(topo, seed=6, tor_frac=0.15,
                                      recover_step=4 * S, **kw)),
        ("switch", FailureSchedule.draw(topo, seed=7, switch_count=1, **kw)),
        ("mixed", FailureSchedule.draw(topo, seed=8, link_frac=0.1,
                                       tor_frac=0.12, switch_count=1, **kw)),
    ]


# ---------------------------------------------------------------------------
# schedule construction + determinism
# ---------------------------------------------------------------------------


class TestScheduleDeterminism:
    def test_two_draws_are_equal(self, topo):
        a = FailureSchedule.draw(topo, seed=11, link_frac=0.2, tor_frac=0.2,
                                 switch_count=1, onset_step=3)
        b = FailureSchedule.draw(topo, seed=11, link_frac=0.2, tor_frac=0.2,
                                 switch_count=1, onset_step=3)
        assert a == b                      # frozen dataclasses, sorted ids
        assert a.seed == 11

    def test_compiled_masks_are_bitwise_stable(self, topo):
        sched = FailureSchedule.draw(topo, seed=3, link_frac=0.2,
                                     switch_count=1, onset_step=2)
        m1 = compile_fault_masks(topo, sched)
        m2 = compile_fault_masks(topo, sched)
        for field in ("switch_id", "pair_switch", "up_onset", "up_detect",
                      "up_recover", "tor_onset", "tor_detect", "tor_recover"):
            assert np.array_equal(getattr(m1, field), getattr(m2, field))

    def test_engine_two_run_determinism(self, topo, cfg, demand):
        sched = FailureSchedule.draw(topo, seed=9, link_frac=0.2,
                                     onset_step=2, detect_lag=2)
        r1 = simulate_rotor_bulk_batch(cfg, demand[None], topo=topo,
                                       max_cycles=6, faults=[sched])
        r2 = simulate_rotor_bulk_batch(cfg, demand[None], topo=topo,
                                       max_cycles=6, faults=[sched])
        assert np.array_equal(r1.finished_frac, r2.finished_frac)
        assert np.array_equal(r1.blackholed_bytes, r2.blackholed_bytes)

    def test_links_sample_realized_uplinks(self, topo):
        ups = set(live_uplinks(topo))
        sched = FailureSchedule.draw(topo, seed=1, link_frac=0.5)
        (ev,) = sched.events
        assert ev.kind == "link"
        assert set(ev.ids) <= ups          # never a non-edge
        assert list(ev.ids) == sorted(ev.ids)

    def test_event_validation(self):
        with pytest.raises(ValueError):
            FailureEvent("cable", (1,), onset_step=0)
        with pytest.raises(ValueError):
            FailureEvent("tor", (1,), onset_step=5, recover_step=5)

    def test_geometry_mismatch_rejected(self, topo):
        other = FailureSchedule(num_racks=4, num_switches=2)
        with pytest.raises(ValueError):
            compile_fault_masks(topo, other)

    def test_failure_set_views_are_sorted(self, topo):
        fs = FailureSchedule.draw(topo, seed=2, link_frac=0.3, tor_frac=0.3,
                                  switch_count=2).to_failure_set()
        assert fs.sorted_uplinks == sorted(fs.uplinks)
        assert fs.sorted_tors == sorted(fs.tors)
        assert fs.sorted_switches == sorted(fs.switches)


# ---------------------------------------------------------------------------
# empty schedule == failure-free path, bit for bit
# ---------------------------------------------------------------------------


class TestEmptyBitIdentity:
    def test_fluid_oracle(self, topo, cfg, demand):
        clean = simulate_rotor_bulk(cfg, demand, topo=topo, max_cycles=6)
        empty = simulate_rotor_bulk(cfg, demand, topo=topo, max_cycles=6,
                                    faults=FailureSchedule.empty(topo))
        assert clean.finished_frac == empty.finished_frac
        assert clean.wire_bytes == empty.wire_bytes
        assert empty.blackholed_bytes == 0.0

    def test_fluid_jax(self, topo, cfg, demand):
        clean = simulate_rotor_bulk_batch(cfg, demand[None], topo=topo,
                                          max_cycles=6)
        empty = simulate_rotor_bulk_batch(
            cfg, demand[None], topo=topo, max_cycles=6,
            faults=[FailureSchedule.empty(topo)])
        assert np.array_equal(clean.finished_frac, empty.finished_frac)
        assert np.array_equal(clean.wire_bytes, empty.wire_bytes)
        assert np.array_equal(clean.residual_bytes, empty.residual_bytes)

    def test_flow_projection_is_identity(self, topo):
        scn = build_scenario("opera", "websearch", 0.1, num_hosts=16,
                             horizon_s=0.06, dt_s=5e-4, tail_s=0.04, seed=0)
        assert apply_flow_faults(scn, FailureSchedule.empty(topo)) is scn
        assert not scn.has_faults


# ---------------------------------------------------------------------------
# fluid pair: oracle <-> jax lockstep under failures
# ---------------------------------------------------------------------------


class TestFluidFaultedParity:
    def test_parity_per_schedule_kind(self, topo, cfg, demand):
        rows = _draws(topo)
        batch = simulate_rotor_bulk_batch(
            cfg, np.broadcast_to(demand, (len(rows),) + demand.shape),
            topo=topo, max_cycles=6, faults=[s for _, s in rows])
        for i, (label, sched) in enumerate(rows):
            o = simulate_rotor_bulk(cfg, demand, topo=topo, max_cycles=6,
                                    faults=sched)
            T = o.slices_run
            np.testing.assert_allclose(
                batch.finished_frac[i, :T], o.finished_frac,
                atol=5e-5, err_msg=label)
            assert np.isclose(batch.blackholed_bytes[i], o.blackholed_bytes,
                              rtol=1e-4, atol=1.0), label

    def test_paced_parity(self, topo, cfg, demand):
        sched = FailureSchedule.draw(topo, seed=5, switch_count=1,
                                     onset_step=topo.num_slices, detect_lag=3)
        o = simulate_rotor_bulk(cfg, demand, topo=topo, max_cycles=8,
                                faults=sched, paced_cycles=4)
        r = simulate_rotor_bulk_batch(cfg, demand[None], topo=topo,
                                      max_cycles=8, faults=[sched],
                                      paced_cycles=4)
        np.testing.assert_allclose(r.finished_frac[0, :o.slices_run],
                                   o.finished_frac, atol=5e-5)


# ---------------------------------------------------------------------------
# blackhole window + conservation
# ---------------------------------------------------------------------------


class TestBlackholeWindow:
    def test_zero_lag_means_zero_blackhole(self, topo, cfg, demand):
        sched = FailureSchedule.draw(topo, seed=4, link_frac=0.2,
                                     onset_step=2, detect_lag=0)
        o = simulate_rotor_bulk(cfg, demand, topo=topo, max_cycles=6,
                                faults=sched)
        assert o.blackholed_bytes == 0.0
        r = simulate_rotor_bulk_batch(cfg, demand[None], topo=topo,
                                      max_cycles=6, faults=[sched])
        assert float(r.blackholed_bytes[0]) == 0.0

    def test_detection_lag_blackholes_then_stops(self, topo, cfg, demand):
        sched = FailureSchedule.draw(topo, seed=4, link_frac=0.2,
                                     onset_step=2, detect_lag=4)
        o = simulate_rotor_bulk(cfg, demand, topo=topo, max_cycles=6,
                                faults=sched)
        assert o.blackholed_bytes > 0.0

    def test_demand_is_conserved(self, topo, cfg, demand):
        # lost-in-flight bytes re-queue at the source (retransmit), so
        # delivered + residual must still account for all offered demand
        sched = FailureSchedule.draw(topo, seed=8, link_frac=0.1,
                                     tor_frac=0.12, switch_count=1,
                                     onset_step=2, detect_lag=3)
        r = simulate_rotor_bulk_batch(cfg, demand[None], topo=topo,
                                      max_cycles=4, faults=[sched])
        total = float(r.total_bytes[0])
        gap = abs(float(r.goodput_bytes[0]) + float(r.residual_bytes[0])
                  - total)
        assert gap < 1e-5 * total

    def test_step_masks_windows(self, topo):
        S = topo.num_slices
        sched = FailureSchedule(
            num_racks=topo.num_racks, num_switches=topo.num_switches,
            events=(FailureEvent("switch", (0,), onset_step=2, detect_lag=3,
                                 recover_step=10),))
        masks = compile_fault_masks(topo, sched)
        sw = switch_id_tensor(topo)
        # pin one slice in which switch 0 serves live edges; vary only
        # the global step to walk the [onset, detect, recover) windows
        sl = next(t for t in range(S) if (sw[t] == 0).any())
        served = sw[sl] == 0
        for g, (real, known) in {1: (False, False), 3: (True, False),
                                 6: (True, True), 11: (False, False)}.items():
            e_real, e_known, _, _, _ = step_masks(masks, 0, g, sl)
            assert bool((e_real[served] > 0).any()) == real, g
            assert bool((e_known[served] > 0).any()) == known, g
            assert not (e_real[~served] > 0).any(), g


# ---------------------------------------------------------------------------
# dynamic masks agree with the static routing view of the same draw
# ---------------------------------------------------------------------------


class TestStaticDynamicConsistency:
    def test_masked_tensor_matches_slice_adjacency(self, topo):
        for label, sched in _draws(topo):
            fs = sched.to_failure_set()
            m = masked_tensor(topo, sched,
                              step=max(ev.detect_step for ev in sched.events))
            for t in range(topo.num_slices):
                static = slice_adjacency(topo, t, fs)
                assert np.array_equal(m[t] != 0, static), (label, t)


# ---------------------------------------------------------------------------
# flow pair: oracle <-> jax lockstep under failures, freeze/retry
# ---------------------------------------------------------------------------


FLOW_KW = dict(num_hosts=16, horizon_s=0.12, dt_s=5e-4, tail_s=0.1)


class TestFlowsFaulted:
    @pytest.fixture(scope="class")
    def scenarios(self, topo):
        base = build_scenario("opera", "websearch", 0.12, seed=0, **FLOW_KW)
        out = [base]
        for _, sched in _draws(topo):
            # rebase the fluid-step timelines onto dt ticks: onset 40,
            # recovery (where drawn) at 160 of the 240-step horizon
            rebased = dataclasses.replace(sched, events=tuple(
                dataclasses.replace(ev, onset_step=40,
                                    recover_step=(160 if ev.recover_step
                                                  is not None else None))
                for ev in sched.events))
            out.append(apply_flow_faults(base, rebased))
        return out

    def test_projection_populates_windows(self, topo):
        scn = build_scenario("opera", "websearch", 0.12, seed=0, **FLOW_KW)
        sched = FailureSchedule.draw(topo, seed=5, tor_frac=0.25,
                                     onset_step=40, detect_lag=5,
                                     recover_step=160)
        f = apply_flow_faults(scn, sched)
        assert f.has_faults and f is not scn
        assert (f.blk_start < NEVER).any()      # some flows blackholed
        assert (f.frz_start < NEVER).any()      # some flows frozen
        assert (f.lat_scale < 1.0).any()        # pools shrink post-detection
        # two projections with the same inputs are bitwise equal
        g = apply_flow_faults(scn, sched)
        for fld in ("blk_start", "blk_end", "frz_start", "frz_end",
                    "lat_scale", "bulk_scale"):
            assert np.array_equal(getattr(f, fld), getattr(g, fld))

    def test_oracle_jax_parity(self, scenarios):
        batch = simulate_flows_batch(scenarios)
        for scn, res in zip(scenarios, batch.results):
            done, _, rem_mid, rem_end, _ = flows._oracle_steps(scn)
            o = finalize(scn, done, rem_mid, rem_end)
            assert o.admitted == res.admitted
            assert np.isclose(o.finished_frac, res.finished_frac,
                              atol=1e-6)
            assert np.isclose(o.fct_mean_ms, res.fct_mean_ms,
                              rtol=1e-4, atol=1e-3)

    def test_trace_parity(self, scenarios):
        batch = simulate_flows_batch(scenarios[:3], trace=True)
        for scn, tr in zip(scenarios[:3], batch.traces):
            _, _, _, _, oracle_tr = flows._oracle_steps(scn, trace=True)
            np.testing.assert_allclose(
                tr, oracle_tr, atol=scn.sizes.max() * 1e-5)

    def test_frozen_flows_retry_after_recovery(self, topo):
        scn = build_scenario("opera", "websearch", 0.12, seed=0, **FLOW_KW)
        sched = FailureSchedule.draw(topo, seed=5, tor_frac=0.25,
                                     onset_step=40, detect_lag=5,
                                     recover_step=120)
        f = apply_flow_faults(scn, sched)
        done, _, _, _, _ = flows._oracle_steps(f)
        frozen = f.frz_start < NEVER
        resumed = frozen & (done > 120)
        assert resumed.any()                # retry-on-recovery, not starvation
        # and the run still makes progress overall (graceful, not collapse)
        clean_done, _, _, _, _ = flows._oracle_steps(scn)
        assert (done >= 0).sum() > 0.5 * (clean_done >= 0).sum()

    def test_two_run_determinism(self, scenarios):
        a = simulate_flows_batch(scenarios)
        b = simulate_flows_batch(scenarios)
        for ra, rb in zip(a.results, b.results):
            assert ra.finished_frac == rb.finished_frac
            assert ra.fct_mean_ms == rb.fct_mean_ms

    def test_fault_free_batch_uses_original_program(self, scenarios):
        # a batch with no fault rows must dispatch to the unfaulted
        # lowering and stay bitwise stable vs a fresh clean build
        clean = build_scenario("opera", "websearch", 0.12, seed=0, **FLOW_KW)
        r1 = simulate_flows_batch([clean]).results[0]
        r2 = simulate_flows_batch([scenarios[0]]).results[0]
        assert r1.finished_frac == r2.finished_frac
        assert r1.fct_mean_ms == r2.fct_mean_ms
