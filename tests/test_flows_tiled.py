"""Tiled streaming flow engine: dense lockstep, padding invariance,
window growth, trace guards, and the streamed-histogram statistics.

The contract: `flows_jax._tiled_step` implements the same per-step math
as the dense `_flow_step` over a sorted, tile-windowed view of the flow
state, and both accumulate completions through the shared
`_hist_accumulate` — so histograms must match *bitwise* whatever the
tile/window/chunk geometry, deficit snapshots to f32 reduction-order
tolerance, and `finalize_streamed` percentiles within one histogram
bin of the dense engine's exact ones.  Appending never-active pad
flows must leave every statistic of both engines bitwise unchanged.
"""
import dataclasses

import numpy as np
import pytest

from repro.netsim import flows
from repro.netsim.faults import (
    NEVER,
    FailureEvent,
    FailureSchedule,
    apply_flow_faults,
)
from repro.netsim.flows import (
    FCT_BIN_LOG2_WIDTH,
    FCT_HIST_BINS,
    build_scenario,
    fct_bin,
    hist_percentile,
    percentile_fct_streamed,
    saturation_load,
)
from repro.netsim.flows_jax import (
    TILED_AUTO_FLOWS,
    resolve_flow_engine,
    saturation_ladder,
    simulate_flows_batch,
)

TINY = dict(num_hosts=16, horizon_s=0.12, dt_s=5e-4, tail_s=0.1)
# deliberately tiny geometry so tile retirement, window growth, and the
# multi-chunk loop are all exercised on test-sized scenarios
TILED_KW = dict(engine="tiled", tile_size=32, window_tiles=1,
                chunk_steps=16)


def _scenarios():
    return [
        build_scenario("opera", "websearch", 0.1, seed=0, **TINY),
        build_scenario("opera", "datamining", 0.35, seed=1, **TINY),
        build_scenario("expander", "websearch", 0.2, seed=2, **TINY),
        build_scenario("rotornet", "websearch", 0.15, seed=3, **TINY),
    ]


def _sched():
    return FailureSchedule(
        num_racks=8, num_switches=2, seed=5,
        events=(FailureEvent("tor", (1,), onset_step=20, detect_lag=10,
                             recover_step=120),
                FailureEvent("switch", (0,), onset_step=40, detect_lag=8,
                             recover_step=200)))


def _faulted_scenarios():
    scns = _scenarios()
    return [apply_flow_faults(s, _sched()) for s in scns[:2]] + scns[2:]


def _assert_tiled_matches_dense(batch):
    dense = simulate_flows_batch(batch, engine="dense")
    tiled = simulate_flows_batch(batch, **TILED_KW)
    for s, d, t, dh, th, drem, trem in zip(
            batch, dense.results, tiled.results, dense.hists, tiled.hists,
            dense.remaining_bytes, tiled.remaining_bytes):
        tag = (s.network, s.workload, s.load)
        # completions flow through the shared binning math: bitwise
        assert np.array_equal(dh, th), tag
        assert d.admitted == t.admitted, tag
        assert d.finished_frac == t.finished_frac, tag
        assert abs(d.backlog_frac - t.backlog_frac) < 1e-5, tag
        np.testing.assert_allclose(trem, drem, rtol=1e-5, atol=1.0,
                                   err_msg=str(tag))
        for f in ("fct_p99_ms_small", "fct_p99_ms_mid", "fct_p99_ms_large"):
            de, ti = getattr(d, f), getattr(t, f)
            if de == 0.0 or ti == 0.0 or np.isinf(de) or np.isinf(ti):
                assert de == ti, (tag, f, de, ti)   # sentinels exact
            else:
                bins = abs(np.log2(ti / de)) / FCT_BIN_LOG2_WIDTH
                assert bins <= 1.0, (tag, f, de, ti, bins)


class TestTiledParity:
    def test_clean_grid_matches_dense(self):
        _assert_tiled_matches_dense(_scenarios())

    def test_faulted_grid_matches_dense(self):
        _assert_tiled_matches_dense(_faulted_scenarios())

    def test_window_growth_is_invisible(self):
        """Starting from a 1-tile window forces capacity doubling; the
        grown run must agree bitwise on histograms with a run whose
        window was ample from the start."""
        scns = _scenarios()
        small = simulate_flows_batch(scns, engine="tiled", tile_size=32,
                                     window_tiles=1, chunk_steps=16)
        ample = simulate_flows_batch(scns, engine="tiled", tile_size=32,
                                     window_tiles=64, chunk_steps=16)
        assert small.peak_window_tiles > 1
        assert small.peak_window_tiles == ample.peak_window_tiles
        for a, b in zip(small.hists, ample.hists):
            assert np.array_equal(a, b)
        for a, b in zip(small.results, ample.results):
            assert a == b


def _pad(scn, npad=37):
    """Append `npad` never-active flows: zero bytes, activation beyond
    the scan, NEVER fault windows."""
    pads = dict(
        arr=np.full(npad, scn.horizon_s, scn.arr.dtype),
        sizes=np.zeros(npad, scn.sizes.dtype),
        start_step=np.full(npad, scn.steps + 1, scn.start_step.dtype),
        is_bulk=np.zeros(npad, scn.is_bulk.dtype),
    )
    if scn.has_faults:
        for f in ("blk_start", "blk_end", "frz_start", "frz_end"):
            pads[f] = np.full(npad, NEVER, getattr(scn, f).dtype)
    return dataclasses.replace(scn, **{
        f: np.concatenate([getattr(scn, f), v]) for f, v in pads.items()
    })


class TestPaddingInvariance:
    @pytest.mark.parametrize("faulted", [False, True])
    @pytest.mark.parametrize("engine_kw", [dict(engine="dense"), TILED_KW],
                             ids=["dense", "tiled"])
    def test_pad_flows_change_nothing(self, faulted, engine_kw):
        scns = _faulted_scenarios() if faulted else _scenarios()
        a = simulate_flows_batch(scns, **engine_kw)
        b = simulate_flows_batch([_pad(s) for s in scns], **engine_kw)
        for i, s in enumerate(scns):
            n = s.num_flows
            assert a.results[i] == b.results[i], (i, s.network, s.workload)
            assert np.array_equal(a.hists[i], b.hists[i])
            assert np.array_equal(a.remaining_bytes[i],
                                  b.remaining_bytes[i][:n])
            assert np.all(b.remaining_bytes[i][n:] == 0.0)


class TestGuardsAndDispatch:
    def test_bad_engine_rejected(self):
        scn = build_scenario("opera", "websearch", 0.1, seed=0, **TINY)
        with pytest.raises(ValueError, match="engine must be"):
            simulate_flows_batch([scn], engine="sparse")

    def test_trace_is_dense_only(self):
        scn = build_scenario("opera", "websearch", 0.1, seed=0, **TINY)
        with pytest.raises(ValueError, match="dense-only"):
            simulate_flows_batch([scn], engine="tiled", trace=True)

    def test_trace_size_gate(self, monkeypatch):
        import repro.netsim.flows_jax as fj

        scn = build_scenario("opera", "websearch", 0.1, seed=0, **TINY)
        monkeypatch.setattr(fj, "TRACE_MAX_ELEMS", 100)
        with pytest.raises(ValueError, match="TRACE_MAX_ELEMS"):
            fj.simulate_flows_batch([scn], trace=True)

    def test_auto_resolution(self):
        assert resolve_flow_engine("auto", 100) == "dense"
        assert resolve_flow_engine("auto", TILED_AUTO_FLOWS) == "tiled"
        # trace mode pins auto to dense whatever the size
        assert resolve_flow_engine("auto", TILED_AUTO_FLOWS,
                                   trace=True) == "dense"
        assert resolve_flow_engine("dense", TILED_AUTO_FLOWS) == "dense"
        assert resolve_flow_engine("tiled", 100) == "tiled"


class TestStreamedStatistics:
    def test_hist_percentile_tracks_numpy(self):
        """Rank-interpolated histogram quantiles stay within one
        log-spaced bin of numpy's exact percentile."""
        rng = np.random.default_rng(11)
        for scale in (0.05, 1.0, 40.0):
            vals = np.clip(rng.lognormal(np.log(scale), 1.2, 4000),
                           2e-2, 5e4)
            hist = np.bincount(fct_bin(vals), minlength=FCT_HIST_BINS)
            for q in (50.0, 90.0, 99.0):
                exact = float(np.percentile(vals, q))
                est = hist_percentile(hist, q)
                bins = abs(np.log2(est / exact)) / FCT_BIN_LOG2_WIDTH
                assert bins <= 1.0, (scale, q, exact, est, bins)

    def test_hist_percentile_empty_is_nan(self):
        assert np.isnan(hist_percentile(np.zeros(FCT_HIST_BINS, np.int64),
                                        99.0))

    def test_streamed_percentile_sentinels(self):
        """Same admission semantics as the exact `percentile_fct`: no
        flows in class -> 0.0, nothing finished -> inf, too few
        completions under saturation -> inf."""
        hist = np.zeros(FCT_HIST_BINS, np.int64)
        assert percentile_fct_streamed(hist, 0, 0) == 0.0
        assert np.isinf(percentile_fct_streamed(hist, 10, 0))
        hist[40] = 3
        assert np.isinf(percentile_fct_streamed(hist, 100, 3))
        hist[40] = 200
        assert np.isfinite(percentile_fct_streamed(hist, 200, 200))


class TestLadders:
    def test_duplicate_loads_grouped_by_index(self):
        """Regression: row grouping is positional, so ladder loads that
        collide in float (or repeat exactly) still yield one row per
        (load, seed) slot."""
        rows = saturation_ladder("opera", "websearch",
                                 [0.04, 0.04, 0.25], seeds=(0,), **TINY)
        assert len(rows) == 3
        assert [r["load"] for r in rows] == [0.04, 0.04, 0.25]
        assert rows[0]["admitted_frac"] == rows[1]["admitted_frac"]

    def test_saturation_knee_engine_parity(self):
        kw = dict(ceiling=0.4, coarse_points=4, refine_points=3,
                  seeds=(0,), **TINY)
        dense = saturation_load("opera", "websearch", engine="dense", **kw)
        tiled = saturation_load("opera", "websearch", engine="tiled", **kw)
        assert dense.load == tiled.load
        assert dense.beyond_grid == tiled.beyond_grid
