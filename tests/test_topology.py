"""Topology-generation invariants (§3.3) — unit + seeded-case tests.

(Property tests formerly ran under hypothesis; the seed environment does
not ship it, so the same invariants are exercised over fixed seeded
parameter grids instead.)
"""
import numpy as np
import pytest

from repro.core.topology import (
    OperaTopology,
    build_opera_topology,
    conjugate,
    lift_matchings,
    random_matchings,
    rotor_schedule,
    sum_matchings,
    verify_factorization,
)


class TestFactorization:
    def test_sum_matchings_factor(self):
        verify_factorization(sum_matchings(8))
        verify_factorization(sum_matchings(9))

    @pytest.mark.parametrize("n", [4, 8, 12, 18, 26, 34, 48])
    def test_random_factorization_even_n(self, n):
        ms = random_matchings(n, seed=n)
        verify_factorization(ms)

    @pytest.mark.parametrize(
        "n,seed", [(4, 0), (8, 1), (10, 17), (14, 4096), (20, 65535)]
    )
    def test_conjugation_preserves_factorization(self, n, seed):
        rng = np.random.default_rng(seed)
        ms = conjugate(sum_matchings(n), rng.permutation(n))
        verify_factorization(ms)

    @pytest.mark.parametrize("n", [2, 4, 6])
    @pytest.mark.parametrize("f", [2, 3, 4])
    def test_lifting(self, n, f):
        lifted = lift_matchings(random_matchings(n, seed=1), f)
        assert len(lifted) == n * f
        verify_factorization(lifted)

    def test_odd_n_supported(self):
        verify_factorization(random_matchings(9, seed=0))


class TestOperaTopology:
    @pytest.fixture(scope="class")
    def topo(self):
        return build_opera_topology(24, 4, seed=0)

    def test_direct_circuit_every_pair_once_per_cycle(self, topo):
        ds = topo.direct_slice()
        off = ~np.eye(topo.num_racks, dtype=bool)
        assert (ds[off] >= 0).all(), "some pair never connected in a cycle"

    def test_staggered_reconfiguration(self, topo):
        # exactly `groups` switches dark per slice, round-robin
        for t in range(topo.num_slices):
            dark = topo.dark_switches(t)
            assert len(dark) == topo.groups

    def test_connectivity_every_slice(self, topo):
        from repro.core.expander import mean_max_path

        for t in range(0, topo.num_slices, 5):
            _, _, disc = mean_max_path(topo.adjacency(t))
            assert disc == 0, f"slice {t} disconnected"

    def test_live_degree_bounded(self, topo):
        for t in range(0, topo.num_slices, 7):
            adj = topo.adjacency(t)
            deg = adj.sum(1)
            assert deg.max() <= topo.u - topo.groups + 1

    def test_grouped_reconfiguration_shortens_cycle(self):
        t1 = build_opera_topology(24, 4, seed=0, groups=1)
        t2 = build_opera_topology(24, 4, seed=0, groups=2)
        assert t2.num_slices == t1.num_slices // 2
        ds = t2.direct_slice()
        assert (ds[~np.eye(24, dtype=bool)] >= 0).all()


class TestRotorSchedule:
    @pytest.mark.parametrize("n", range(2, 18))
    def test_rotor_schedule_covers_all_pairs_once(self, n):
        seen = np.zeros((n, n), dtype=int)
        for pairs in rotor_schedule(n):
            for s, d in pairs:
                seen[s, d] += 1
        off = ~np.eye(n, dtype=bool)
        assert (seen[off] == 1).all()
        assert (np.diag(seen) == 0).all()

    @pytest.mark.parametrize("n", range(2, 18))
    def test_rotor_schedule_matchings_are_involutions(self, n):
        for pairs in rotor_schedule(n):
            d = dict(pairs)
            for s, t in pairs:
                assert d[t] == s
