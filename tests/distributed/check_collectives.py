"""Standalone multi-device check for the rotor collectives.

Run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(set here defensively too — MUST be set before jax import).  Asserts rotor
collectives match their lax reference semantics on a (pod=2, data=4) mesh.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax import lax  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import collectives as C  # noqa: E402

mesh = compat.make_mesh((2, 4), ("pod", "data"))
rng = np.random.default_rng(0)


def run(fn, x, in_spec, out_spec):
    f = compat.shard_map(fn, mesh=mesh, in_specs=(in_spec,), out_specs=out_spec,
                         check_vma=False)
    return jax.jit(f)(x)


def check(name, got, want, atol=1e-5):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=atol,
                               rtol=1e-5, err_msg=name)
    print(f"ok: {name}")


# ---- rotor_all_reduce over data (4 shards), batch-sharded input ----------
x = rng.normal(size=(8, 6)).astype(np.float32)

got = run(lambda a: C.rotor_all_reduce(a, "data"), x, P("data", None),
          P("data", None))
want = run(lambda a: lax.psum(a, "data"), x, P("data", None), P("data", None))
check("rotor_all_reduce(rs_ag) == psum", got, want)

got = run(lambda a: C.rotor_all_reduce(a, "data", mode="direct"), x,
          P("data", None), P("data", None))
check("rotor_all_reduce(direct) == psum", got, want)

# ---- hierarchical over (data, pod) ---------------------------------------
x2 = rng.normal(size=(8, 4)).astype(np.float32)
got = run(lambda a: C.hierarchical_rotor_all_reduce(a, "data", "pod"), x2,
          P(("pod", "data"), None), P(("pod", "data"), None))
want = run(lambda a: lax.psum(a, ("pod", "data")), x2,
           P(("pod", "data"), None), P(("pod", "data"), None))
check("hierarchical_rotor_all_reduce == psum(pod,data)", got, want)

# ---- reduce-scatter / all-gather round trip ------------------------------
x3 = rng.normal(size=(8, 8)).astype(np.float32)  # per-shard (2, 8) -> 16 elts


def rs_ag(a):
    c = C.rotor_reduce_scatter(a, "data")
    full = C.rotor_all_gather(c, "data").reshape(-1)
    return full[: a.size].reshape(a.shape)


got = run(rs_ag, x3, P("data", None), P("data", None))
want = run(lambda a: lax.psum(a, "data"), x3, P("data", None), P("data", None))
check("rotor RS+AG == psum", got, want)

# ---- all-to-all (incl. VLB) ----------------------------------------------
# per-shard buffer (4, 3): chunk j destined for data-shard j
xa = rng.normal(size=(2, 4 * 4, 3)).astype(np.float32)  # sharded over pod too


def a2a_rotor(a):  # a: (1, 4, 3) per shard -> drop pod-local leading dim
    return C.rotor_all_to_all(a[0], "data")[None]


def a2a_ref(a):
    return lax.all_to_all(a, "data", split_axis=0, concat_axis=0, tiled=True)


got = run(a2a_rotor, xa, P("pod", "data", None), P("pod", "data", None))
want = run(lambda a: a2a_ref(a[0])[None], xa, P("pod", "data", None),
           P("pod", "data", None))
check("rotor_all_to_all == lax.all_to_all", got, want)

got = run(lambda a: C.rotor_all_to_all(a[0], "data", vlb=True)[None], xa,
          P("pod", "data", None), P("pod", "data", None))
check("rotor_all_to_all(vlb) == lax.all_to_all", got, want)

# ---- expander latency path ------------------------------------------------
xs = rng.normal(size=(8, 5)).astype(np.float32)
got = run(lambda a: C.expander_all_gather(a, "data", u=3), xs,
          P("data", None), P("data", None, None))
want = run(lambda a: lax.all_gather(a, "data"), xs, P("data", None),
           P("data", None, None))
check("expander_all_gather == all_gather", got, want)

got = run(lambda a: C.expander_psum_latency(a, "data"), xs, P("data", None),
          P("data", None))
want = run(lambda a: lax.psum(a, "data"), xs, P("data", None), P("data", None))
check("expander_psum_latency == psum", got, want)

# ---- compressed all-reduce: error feedback converges ----------------------
xc = rng.normal(size=(8, 16)).astype(np.float32)


def comp(a):
    total, err = C.compressed_rotor_all_reduce(a, "data", None, bits=8)
    return total


got = run(comp, xc, P("data", None), P("data", None))
want = run(lambda a: lax.psum(a, "data"), xc, P("data", None), P("data", None))
rel = np.abs(np.asarray(got) - np.asarray(want)).max() / np.abs(want).max()
assert rel < 0.05, f"int8 compressed AR too lossy: rel={rel}"
print(f"ok: compressed_rotor_all_reduce within int8 tolerance (rel={rel:.4f})")

# ---- wire-byte accounting sanity ------------------------------------------
st = C.schedule_stats(8, u=3)
assert st["rotor_a2a_vlb_bytes"] == 2 * st["rotor_a2a_bytes"]
assert st["bandwidth_tax_latency"] >= 1.0
print("ok: schedule_stats")

print("ALL COLLECTIVE CHECKS PASSED")
