"""Multi-device end-to-end checks on a (pod=2, data=2, model=2) mesh:

1. GSPMD trainer with FSDP+TP shardings == single-device trainer (loss).
2. MoE rotor a2a dispatch == xla all_to_all dispatch == single-device.
3. opera-dp trainer with rotor grad sync == single-device update.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
from repro import compat  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import reduced_config  # noqa: E402
from repro.data.pipeline import SyntheticLM  # noqa: E402
from repro.compat import make_mesh  # noqa: E402
from repro.launch.mesh import pctx_for_mesh  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.models.model import loss_fn, param_shapes  # noqa: E402
from repro.models.parallel import single_device_ctx  # noqa: E402
from repro.models.sharding import param_shardings  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.train.opera_dp import (  # noqa: E402
    init_opera_dp_state,
    make_opera_dp_train_step,
)
from repro.train.trainer import init_train_state, make_train_step  # noqa: E402

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)

# ---------------- dense arch: gspmd + opera-dp vs single device ------------
cfg = reduced_config(get_config("smollm-360m")).replace(
    num_layers=2, vocab_size=64, grad_sync="rotor"
)
params = init_params(cfg, jax.random.key(0))
src = SyntheticLM(cfg.vocab_size, 16, 8, seed=0)
batch = jax.tree.map(jnp.asarray, src.batch_at(0))

# single-device reference
s_ref = init_train_state(cfg, params)
s_ref, m_ref = jax.jit(make_train_step(cfg, single_device_ctx(), opt))(
    s_ref, batch
)
ref_loss = float(m_ref["loss"])

# gspmd multi-device (params sharded by rules; batch sharded over dp)
pctx = pctx_for_mesh(mesh, grad_sync="xla")
shardings = param_shardings(param_shapes(cfg), cfg, pctx)
with compat.set_mesh(mesh):
    sh_params = jax.device_put(params, shardings)
    state = init_train_state(cfg, sh_params)
    bsh = jax.tree.map(
        lambda x: jax.device_put(
            x, NamedSharding(mesh, P(("pod", "data")))
        ),
        batch,
    )
    state, m = jax.jit(make_train_step(cfg, pctx, opt))(state, bsh)
assert abs(float(m["loss"]) - ref_loss) < 1e-3, (float(m["loss"]), ref_loss)
print("ok: gspmd multi-device trainer matches single-device loss")

# rotor pod-sync trainer
pctx_r = pctx_for_mesh(mesh, grad_sync="rotor")
with compat.set_mesh(mesh):
    state_r = init_train_state(cfg, jax.device_put(params, shardings))
    state_r, m_r = jax.jit(make_train_step(cfg, pctx_r, opt))(state_r, bsh)
assert abs(float(m_r["loss"]) - ref_loss) < 1e-3
pa = jax.tree.leaves(state["params"])
pb = jax.tree.leaves(state_r["params"])
for x, y in zip(pa, pb):
    np.testing.assert_allclose(np.asarray(x, np.float32),
                               np.asarray(y, np.float32), atol=2e-4, rtol=2e-4)
print("ok: rotor pod-sync trainer matches gspmd updates")

# opera-dp explicit trainer
with compat.set_mesh(mesh):
    s_dp = init_opera_dp_state(params)
    s_dp, m_dp = jax.jit(make_opera_dp_train_step(cfg, pctx_r, opt))(s_dp, batch)
assert abs(float(m_dp["loss"]) - ref_loss) < 1e-3
print("ok: opera-dp explicit trainer matches reference loss")

# ---------------- MoE arch: rotor vs xla dispatch ---------------------------
mcfg = reduced_config(get_config("qwen3-moe-30b-a3b"))
mparams = init_params(mcfg, jax.random.key(1))
msrc = SyntheticLM(mcfg.vocab_size, 16, 8, seed=1)
mbatch = jax.tree.map(jnp.asarray, msrc.batch_at(0))

ref_total, _ = loss_fn(mparams, mbatch, mcfg, single_device_ctx())
losses = {}
for dispatch in ("rotor", "rotor_vlb", "xla"):
    pctx_m = pctx_for_mesh(mesh, moe_dispatch=dispatch)
    mshard = param_shardings(param_shapes(mcfg), mcfg, pctx_m)
    with compat.set_mesh(mesh):
        shp = jax.device_put(mparams, mshard)
        bsh = jax.tree.map(
            lambda x: jax.device_put(
                x, NamedSharding(mesh, P(("pod", "data")))
            ),
            mbatch,
        )
        total, _ = jax.jit(
            lambda p, b: loss_fn(p, b, mcfg, pctx_m)
        )(shp, bsh)
    losses[dispatch] = float(total)
    print(f"ok: moe dispatch={dispatch} loss={losses[dispatch]:.5f}")

# all dispatch modes must agree with each other exactly (same math)
assert abs(losses["rotor"] - losses["xla"]) < 1e-4
assert abs(losses["rotor_vlb"] - losses["xla"]) < 1e-4
# and with the single-device reference up to capacity-drop differences
# (sharded dispatch has per-shard capacity): allow small drift
assert abs(losses["xla"] - float(ref_total)) < 0.2, (losses, float(ref_total))
print("ALL SHARDED CHECKS PASSED")
