"""Multi-device rotor-collective semantics (subprocess: needs 8 fake XLA
devices, which must be configured BEFORE jax import — so these run in
fresh interpreters, leaving the main pytest process at 1 device)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(script: str, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "distributed" / script)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_rotor_collectives_match_lax_references():
    out = _run("check_collectives.py")
    assert "ALL COLLECTIVE CHECKS PASSED" in out


@pytest.mark.slow
def test_sharded_train_and_moe_dispatch():
    out = _run("check_sharded_train.py")
    assert "ALL SHARDED CHECKS PASSED" in out
