"""Cycle-time arithmetic (§4.1) and traffic classification (§3.4)."""
import pytest

from repro.configs.opera_paper import OPERA_648
from repro.core.classify import Classifier, TrafficClass, effective_tax_rate
from repro.core.schedule import cycle_timing, scaled_cycle_table


class TestCycleTiming:
    def test_648_design_point_matches_paper(self):
        t = cycle_timing(OPERA_648)
        # paper: eps = 90 us, slice ~ 100 us, duty 98 %, cycle 10.7 ms,
        # bulk cutoff ~ 15 MB.  our first-principles model lands within
        # ~15 % (the paper rounds eps down to 90).
        assert 85 <= t.epsilon_us <= 110
        assert 0.97 <= t.duty_cycle <= 0.99
        assert 9.5 <= t.cycle_ms <= 13.0
        assert 11 <= t.bulk_cutoff_mb <= 18
        assert t.num_slices == 108

    def test_guard_band_sensitivity(self):
        t = cycle_timing(OPERA_648)
        # §3.5: ~1 %/us low-latency, ~0.2 %/us bulk
        assert 0.8e-2 <= t.ll_capacity_loss_per_guard_us <= 1.2e-2
        assert 0.1e-2 <= t.bulk_capacity_loss_per_guard_us <= 0.25e-2

    def test_grouped_reconfig_scaling(self):
        rows = scaled_cycle_table()
        # Appendix B: cycle time grows ~linearly (not quadratically) with k
        k0, kN = rows[0], rows[-1]
        growth = kN["relative_cycle"]
        k_ratio = kN["k"] / k0["k"]
        assert growth <= k_ratio * 1.6  # linear-ish, not (k_ratio)^2
        assert kN["bulk_cutoff_mb"] > k0["bulk_cutoff_mb"]


class TestClassifier:
    def test_size_threshold(self):
        c = Classifier()
        assert c.classify(1_000) is TrafficClass.LATENCY
        assert c.classify(20 * 2**20) is TrafficClass.BULK

    def test_app_tag_overrides(self):
        c = Classifier()
        assert c.classify(100, app_tag=TrafficClass.BULK) is TrafficClass.BULK

    def test_effective_tax_rate_matches_paper(self):
        # §5.1: 4 % of bytes indirect at avg ~3.1 hops -> ~8.4 % tax
        rate = effective_tax_rate(0.04, 3.1)
        assert 0.06 <= rate <= 0.10
