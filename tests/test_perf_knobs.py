"""The §Perf levers must preserve semantics: chunked CE == standard CE,
bf16 normalize ~= fp32 normalize, layouts don't change the math."""
import jax

from repro import compat
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced_config
from repro.models import init_params, loss_fn
from repro.models.layers import apply_norm, init_norm
from repro.models.model import softmax_xent, softmax_xent_chunked
from repro.models.parallel import single_device_ctx

RNG = np.random.default_rng(0)


class TestChunkedCE:
    @pytest.mark.parametrize("V,chunk", [(64, 16), (96, 32), (50, 50), (50, 7)])
    def test_matches_full_loss(self, V, chunk):
        B, S, D = 2, 8, 16
        x = jnp.asarray(RNG.normal(size=(B, S, D)), jnp.float32)
        head = jnp.asarray(RNG.normal(size=(D, V)) * 0.2, jnp.float32)
        tgt = jnp.asarray(RNG.integers(0, V, (B, S)), jnp.int32)
        full, ce_f = softmax_xent(x @ head, tgt)
        chk, ce_c = softmax_xent_chunked(x, head, tgt, chunk)
        assert float(ce_f) == pytest.approx(float(ce_c), rel=1e-5)
        assert float(full) == pytest.approx(float(chk), rel=1e-5)

    def test_gradients_match(self):
        B, S, D, V = 1, 4, 8, 32
        x = jnp.asarray(RNG.normal(size=(B, S, D)), jnp.float32)
        head = jnp.asarray(RNG.normal(size=(D, V)) * 0.2, jnp.float32)
        tgt = jnp.asarray(RNG.integers(0, V, (B, S)), jnp.int32)
        g1 = jax.grad(lambda h: softmax_xent(x @ h, tgt)[0])(head)
        g2 = jax.grad(lambda h: softmax_xent_chunked(x, h, tgt, 8)[0])(head)
        np.testing.assert_allclose(g1, g2, atol=1e-5, rtol=1e-5)

    def test_loss_fn_chunked_config_matches(self):
        cfg = reduced_config(get_config("smollm-360m")).replace(num_layers=2)
        params = init_params(cfg, jax.random.key(0))
        batch = {
            "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)),
                                  jnp.int32),
            "targets": jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)),
                                   jnp.int32),
        }
        t1, _ = loss_fn(params, batch, cfg, single_device_ctx())
        t2, _ = loss_fn(params, batch, cfg.replace(loss_chunk_vocab=64),
                        single_device_ctx())
        assert float(t1) == pytest.approx(float(t2), rel=1e-4)


class TestNormDowncast:
    @pytest.mark.parametrize("kind", ["rmsnorm", "layernorm"])
    def test_bf16_normalize_close(self, kind):
        p = init_norm(kind, 64, jnp.float32)
        x = jnp.asarray(RNG.normal(size=(4, 16, 64)), jnp.bfloat16)
        a = apply_norm(kind, p, x, upcast=True)
        b = apply_norm(kind, p, x, upcast=False)
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=3e-2, rtol=3e-2,
        )

    def test_model_trains_with_downcast_norm(self):
        cfg = reduced_config(get_config("yi-9b")).replace(
            num_layers=2, norm_upcast=False
        )
        params = init_params(cfg, jax.random.key(0))
        batch = {
            "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)),
                                  jnp.int32),
            "targets": jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)),
                                   jnp.int32),
        }
        (total, _), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, single_device_ctx()),
            has_aux=True,
        )(params)
        assert jnp.isfinite(total)


class TestLayouts:
    def test_dp_only_pctx_math_unchanged(self):
        """dp_only must be a layout change only: same loss on 1 device."""
        from repro.compat import make_mesh
        from repro.launch.mesh import pctx_for_mesh

        cfg = reduced_config(get_config("smollm-360m")).replace(num_layers=2)
        params = init_params(cfg, jax.random.key(0))
        batch = {
            "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)),
                                  jnp.int32),
            "targets": jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)),
                                   jnp.int32),
        }
        mesh = make_mesh((1, 1), ("data", "model"))
        with compat.set_mesh(mesh):
            t1, _ = loss_fn(params, batch, cfg, pctx_for_mesh(mesh))
            t2, _ = loss_fn(params, batch, cfg,
                            pctx_for_mesh(mesh, layout="dp_only"))
            t3, _ = loss_fn(params, batch, cfg,
                            pctx_for_mesh(mesh, layout="tp_only"))
        assert float(t1) == pytest.approx(float(t2), rel=1e-5)
        assert float(t1) == pytest.approx(float(t3), rel=1e-5)
