"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (the FULL
configs are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.configs.base import reduced_config
from repro.models import (
    forward_decode,
    forward_prefill,
    init_params,
    loss_fn,
)
from repro.models.parallel import single_device_ctx

B, S = 2, 16
PCTX = single_device_ctx()


def _batch(cfg, rng):
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "encdec":
        b["encoder_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16
        )
    if cfg.family == "vlm":
        b["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_image_tokens, cfg.d_model)), jnp.bfloat16
        )
    return b


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ALL_ARCHS:
        cfg = reduced_config(get_config(arch))
        out[arch] = (cfg, init_params(cfg, jax.random.key(0)))
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_finite(arch, built):
    cfg, params = built[arch]
    rng = np.random.default_rng(1)
    (total, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, _batch(cfg, rng), cfg, PCTX), has_aux=True
    )(params)
    assert jnp.isfinite(total)
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
    ))
    assert jnp.isfinite(gnorm) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_then_decode(arch, built):
    cfg, params = built[arch]
    rng = np.random.default_rng(2)
    logits, caches = forward_prefill(params, _batch(cfg, rng), cfg, PCTX)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    logits2, caches2 = forward_decode(params, tok, pos, caches, cfg, PCTX)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())
    # cache structure unchanged
    assert jax.tree_util.tree_structure(caches) == \
        jax.tree_util.tree_structure(caches2)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_registered_dims(arch):
    """Sanity-pin the published full-size dims (no allocation)."""
    cfg = get_config(arch)
    from repro.models.model import param_shapes

    shapes = param_shapes(cfg)
    n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    expected = {
        "qwen3-moe-30b-a3b": (29e9, 32e9),
        "deepseek-moe-16b": (15e9, 18e9),
        "falcon-mamba-7b": (6.5e9, 8e9),
        "seamless-m4t-large-v2": (1.1e9, 1.8e9),
        "recurrentgemma-2b": (2.3e9, 3.2e9),
        "llama-3.2-vision-90b": (80e9, 95e9),
        "smollm-360m": (0.3e9, 0.45e9),
        "yi-9b": (8e9, 10e9),
        "qwen1.5-110b": (105e9, 118e9),
        "stablelm-12b": (11e9, 13.5e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n:,} params"


def test_decode_matches_prefill_continuation():
    """Decode step must agree with re-running prefill one token longer
    (the KV-cache/state correctness test), per family representative."""
    rng = np.random.default_rng(3)
    for arch in ("smollm-360m", "falcon-mamba-7b", "recurrentgemma-2b"):
        cfg = reduced_config(get_config(arch))
        params = init_params(cfg, jax.random.key(0))
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S + 1)), jnp.int32)
        # prefill on S tokens (with room for new ones), decode token S
        _, caches = forward_prefill(
            params, {"tokens": toks[:, :S]}, cfg, single_device_ctx(),
            cache_len=S + 4,
        )
        logits_d, _ = forward_decode(
            params, toks[:, S:S + 1], jnp.array([S], jnp.int32), caches,
            cfg, single_device_ctx(),
        )
        # reference: prefill on S+1 tokens
        logits_f, _ = forward_prefill(
            params, {"tokens": toks}, cfg, single_device_ctx()
        )
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32),
            np.asarray(logits_f, np.float32),
            atol=5e-2, rtol=5e-2,
        )
