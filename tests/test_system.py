"""End-to-end behaviour of the paper's system: the Opera properties that
make the whole design work, checked against each other (not just units).

1. The same matching schedule drives BOTH the network simulator and the
   JAX collectives — one design-time artifact, two consumers.
2. The two traffic classes trade exactly as §3 describes: bulk is
   tax-free but waits; latency is immediate but taxed.
3. The end-to-end cycle arithmetic makes the 15 MB bulk/latency split
   self-consistent with the workloads it serves.
"""
import numpy as np
import pytest

from repro.configs.opera_paper import OPERA_648
from repro.core.classify import Classifier, TrafficClass, effective_tax_rate
from repro.core.collectives import schedule_stats
from repro.core.schedule import cycle_timing
from repro.core.topology import build_opera_topology, rotor_schedule
from repro.netsim.fluid import simulate_rotor_bulk
from repro.netsim.workloads import byte_fraction_below, demand_all_to_all


def test_one_schedule_two_consumers():
    """The collective schedule is the N-matching factorization the network
    uses: every ordered pair served exactly once — so a rotor collective's
    wire-byte ledger equals the fluid simulator's tax accounting."""
    n = 16
    sched = rotor_schedule(n)
    seen = np.zeros((n, n))
    for pairs in sched:
        for s, d in pairs:
            seen[s, d] += 1
    assert (seen[~np.eye(n, dtype=bool)] == 1).all()
    st = schedule_stats(n)
    # bulk a2a: (n-1)/n of payload crosses exactly one link -> tax 0
    assert st["rotor_a2a_bytes"] == pytest.approx((n - 1) / n)
    # and the fluid sim measures the same zero tax on a real shuffle
    r = simulate_rotor_bulk(
        OPERA_648, demand_all_to_all(108, 6, 100e3), vlb=False, max_cycles=40
    )
    assert r.bandwidth_tax < 0.01


def test_traffic_class_tradeoff():
    """Latency class pays a tax >= (diameter-1); bulk class pays zero but
    waits up to a cycle — both sides of §3.4's per-packet choice."""
    st = schedule_stats(16, u=3)
    assert st["bandwidth_tax_latency"] >= 1.0     # multi-hop tax
    assert st["rotor_a2a_vlb_bytes"] == pytest.approx(
        2 * st["rotor_a2a_bytes"]
    )                                              # VLB: exactly 100 % tax
    t = cycle_timing(OPERA_648)
    assert t.cycle_ms < 15                         # bounded bulk wait


def test_cutoff_is_self_consistent_with_workloads():
    """The 15 MB cutoff derived from the cycle time must (a) put ~all
    Websearch bytes on the latency path and (b) only a few % of
    Datamining bytes — which is what makes the 8.4 % effective tax and
    the 40 %-load headline possible."""
    t = cycle_timing(OPERA_648)
    cutoff = t.bulk_cutoff_mb * 2**20
    assert byte_fraction_below("websearch", cutoff) > 0.9
    dm = byte_fraction_below("datamining", cutoff)
    assert dm < 0.08
    assert 0.04 <= effective_tax_rate(dm, 3.34) <= 0.11


def test_classifier_respects_cycle_derived_cutoff():
    t = cycle_timing(OPERA_648)
    c = Classifier(bulk_cutoff_bytes=int(t.bulk_cutoff_mb * 2**20))
    assert c.classify(100 * 2**20) is TrafficClass.BULK
    assert c.classify(1 * 2**20) is TrafficClass.LATENCY


def test_topology_survives_schedule_perturbation():
    """Grouped reconfiguration (App. B) halves the cycle but must keep
    both invariants: per-slice connectivity and full pair coverage.
    Per §3.1.1 grouping applies to many-switch networks: u - groups live
    matchings must still form an expander (u=8, groups=2 -> 6 live)."""
    topo = build_opera_topology(24, 8, seed=1, groups=2)
    ds = topo.direct_slice()
    assert (ds[~np.eye(24, dtype=bool)] >= 0).all()
    from repro.core.expander import mean_max_path

    for t in range(topo.num_slices):
        _, _, disc = mean_max_path(topo.adjacency(t))
        assert disc == 0
