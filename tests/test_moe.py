"""MoE dispatch correctness: capacity dispatch == explicit per-token sum."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced_config
from repro.models.moe import (
    _capacity,
    _rank_within_expert,
    _topk_route,
    apply_moe,
    init_moe,
)
from repro.models.parallel import single_device_ctx

RNG = np.random.default_rng(0)


def _dense_reference(p, x, cfg):
    """Explicit per-token top-k expert sum (no capacity, no dropping)."""
    B, S, D = x.shape
    m = cfg.moe
    T = B * S
    xt = x.reshape(T, D)
    logits = xt.astype(jnp.float32) @ p["router"]
    gates, idx, _ = _topk_route(logits, m.top_k)
    y = jnp.zeros((T, D), jnp.float32)
    for t in range(T):
        acc = jnp.zeros((D,), jnp.float32)
        for j in range(m.top_k):
            e = int(idx[t, j])
            h = xt[t].astype(jnp.float32)
            g = jax.nn.silu(h @ p["w_gate"][e].astype(jnp.float32))
            u = h @ p["w_up"][e].astype(jnp.float32)
            acc += gates[t, j] * ((g * u) @ p["w_down"][e].astype(jnp.float32))
        y = y.at[t].set(acc)
    out = y.reshape(B, S, D)
    if m.num_shared_experts:
        h = x.astype(jnp.float32)
        g = jax.nn.silu(h @ p["shared_gate"].astype(jnp.float32))
        u = h @ p["shared_up"].astype(jnp.float32)
        out = out + (g * u) @ p["shared_down"].astype(jnp.float32)
    return out


@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "deepseek-moe-16b"])
def test_capacity_dispatch_matches_dense(arch):
    cfg = reduced_config(get_config(arch))
    # huge capacity factor -> no token dropped -> exact equality
    cfg = cfg.replace(moe=cfg.moe.__class__(
        **{**cfg.moe.__dict__, "capacity_factor": 8.0}
    ))
    p = init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 4, cfg.d_model)) * 0.3, jnp.float32)
    got, aux = apply_moe(p, x, cfg, single_device_ctx())
    want = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), atol=2e-3, rtol=2e-3
    )
    assert float(aux) > 0


def test_capacity_drops_overflow_tokens():
    cfg = reduced_config(get_config("qwen3-moe-30b-a3b"))
    cfg = cfg.replace(moe=cfg.moe.__class__(
        **{**cfg.moe.__dict__, "capacity_factor": 0.25}
    ))
    p = init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    got, _ = apply_moe(p, x, cfg, single_device_ctx())
    assert bool(jnp.isfinite(got.astype(jnp.float32)).all())


def test_rank_within_expert():
    e = jnp.asarray([2, 0, 2, 2, 1, 0], jnp.int32)
    rank = _rank_within_expert(e, 3)
    np.testing.assert_array_equal(np.asarray(rank), [0, 0, 1, 2, 0, 1])


def test_topk_gates_normalized():
    logits = jnp.asarray(RNG.normal(size=(10, 8)), jnp.float32)
    gates, idx, probs = _topk_route(logits, 3)
    np.testing.assert_allclose(gates.sum(-1), 1.0, atol=1e-5)
    assert int(idx.max()) < 8
    np.testing.assert_allclose(probs.sum(-1), 1.0, atol=1e-5)


def test_capacity_rounding():
    assert _capacity(64, 2, 8, 1.25) == 20
    assert _capacity(1, 1, 8, 1.0) % 4 == 0
