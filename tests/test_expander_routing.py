"""Expander diagnostics + per-slice routing + failure handling (§3.6.2, §5.5)."""
import numpy as np
import pytest

from repro.core.expander import (
    hop_distances,
    mean_max_path,
    path_length_cdf,
    ramanujan_bound,
    random_regular_expander,
    spectral_gap,
)
from repro.core.routing import (
    FailureSet,
    bfs_next_hop,
    compute_routes,
    connectivity_loss,
    path_stretch,
    ruleset_size,
    slice_adjacency,
)
from repro.core.topology import build_opera_topology


@pytest.fixture(scope="module")
def topo():
    return build_opera_topology(24, 4, seed=1)


class TestExpander:
    def test_random_union_is_good_expander(self):
        adj = random_regular_expander(32, 5, seed=0)
        gap = spectral_gap(adj)
        assert gap > 0.5 * ramanujan_bound(5)
        mean_h, max_h, disc = mean_max_path(adj)
        assert disc == 0 and max_h <= 4

    def test_hop_distances_match_bfs_walk(self):
        adj = random_regular_expander(20, 3, seed=2)
        dist, nxt = bfs_next_hop(adj)
        d2 = hop_distances(adj)
        assert np.array_equal(dist, d2)
        # walking next_hop reproduces dist
        for s in range(20):
            for d in range(20):
                if s == d or dist[s, d] < 0:
                    continue
                cur, hops = s, 0
                while cur != d and hops <= dist[s, d]:
                    cur = int(nxt[cur, d])
                    hops += 1
                assert cur == d and hops == dist[s, d]

    def test_path_cdf_monotone(self):
        adj = random_regular_expander(24, 4, seed=3)
        cdf = path_length_cdf(adj)
        vals = [cdf[h] for h in sorted(cdf)]
        assert all(b >= a for a, b in zip(vals, vals[1:]))
        assert abs(vals[-1] - 1.0) < 1e-9


class TestFailures:
    def test_no_failures_fully_connected(self, topo):
        loss = connectivity_loss(
            topo, FailureSet(), slices=range(0, topo.num_slices, 4)
        )
        assert loss["worst_slice_disconnected_frac"] == 0.0

    def test_single_link_failure_tolerated(self, topo):
        loss = connectivity_loss(
            topo, FailureSet(links={(0, 1), (2, 3)}),
            slices=range(0, topo.num_slices, 4),
        )
        assert loss["worst_slice_disconnected_frac"] == 0.0

    def test_switch_failure_tolerated(self, topo):
        # u=4: losing 1 of 4 switches leaves a connected expander (§5.5)
        loss = connectivity_loss(
            topo, FailureSet(switches={0}), slices=range(0, topo.num_slices, 4)
        )
        assert loss["worst_slice_disconnected_frac"] == 0.0

    def test_tor_failure_excludes_failed(self, topo):
        loss = connectivity_loss(
            topo, FailureSet(tors={5}), slices=range(0, topo.num_slices, 4)
        )
        assert loss["worst_slice_disconnected_frac"] < 0.05

    def test_failures_stretch_paths(self, topo):
        base = path_stretch(topo, FailureSet(), slices=[0, 5, 10])
        hurt = path_stretch(
            topo, FailureSet(switches={0}), slices=[0, 5, 10]
        )
        assert hurt["mean_path"] >= base["mean_path"]

    def test_routes_recomputed_around_failure(self, topo):
        f = FailureSet(links={(0, 1)})
        routes = compute_routes(topo, f, slices=[0])[0]
        adj = slice_adjacency(topo, 0, f)
        # next hop never uses the failed link
        for s in range(topo.num_racks):
            for d in range(topo.num_racks):
                h = routes.next_hop[s, d]
                if h >= 0:
                    assert adj[s, h]


def test_ruleset_scales_quadratically():
    a, b = ruleset_size(108), ruleset_size(216)
    assert 3.5 < b / a < 4.5
