"""Model-layer unit tests: attention paths, convs, scans, rope.

(Property tests formerly ran under hypothesis; the seed environment does
not ship it, so the same invariants run over fixed parameter grids.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced_config
from repro.models.attention import (
    block_local_attention,
    chunked_attention,
    decode_attention,
)
from repro.models.layers import apply_causal_conv, apply_rope, init_causal_conv

RNG = np.random.default_rng(0)


def _naive_attn(q, k, v, causal=True, window=0):
    from repro.kernels.flash_attention.ref import flash_attention_ref

    return flash_attention_ref(q, k, v, causal=causal, window=window)


class TestChunkedAttention:
    @pytest.mark.parametrize(
        "S,heads,chunk",
        [
            (16, (2, 1), 8), (16, (4, 2), 16), (16, (3, 3), 64),
            (32, (2, 1), 64), (32, (4, 2), 8), (32, (3, 3), 16),
            (64, (2, 1), 16), (64, (4, 2), 64), (64, (3, 3), 8),
        ],
    )
    def test_matches_naive(self, S, heads, chunk):
        Hq, Hkv = heads
        q = jnp.asarray(RNG.normal(size=(2, Hq, S, 16)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(2, Hkv, S, 16)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(2, Hkv, S, 16)), jnp.float32)
        pos = jnp.arange(S, dtype=jnp.int32)
        got = chunked_attention(q, k, v, pos, pos, causal=True,
                                chunk_q=chunk, chunk_k=chunk)
        want = _naive_attn(q, k, v)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    def test_window_matches_naive(self):
        S, W = 64, 16
        q = jnp.asarray(RNG.normal(size=(1, 2, S, 8)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(1, 2, S, 8)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(1, 2, S, 8)), jnp.float32)
        pos = jnp.arange(S, dtype=jnp.int32)
        got = chunked_attention(q, k, v, pos, pos, causal=True, window=W,
                                chunk_q=16, chunk_k=16)
        want = _naive_attn(q, k, v, window=W)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    def test_block_local_matches_naive(self):
        S, W = 64, 16
        q = jnp.asarray(RNG.normal(size=(1, 4, S, 8)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(1, 2, S, 8)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(1, 2, S, 8)), jnp.float32)
        pos = jnp.arange(S, dtype=jnp.int32)
        got = block_local_attention(q, k, v, pos, W)
        want = _naive_attn(q, k, v, causal=True, window=W)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    def test_decode_matches_last_row_of_full(self):
        S = 32
        q_full = jnp.asarray(RNG.normal(size=(2, 4, S, 8)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(2, 2, S, 8)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(2, 2, S, 8)), jnp.float32)
        full = _naive_attn(q_full, k, v, causal=True)
        got = decode_attention(
            q_full[:, :, -1:], k, v, kv_len=jnp.full((2,), S, jnp.int32)
        )
        np.testing.assert_allclose(got[:, :, 0], full[:, :, -1],
                                   atol=2e-5, rtol=2e-5)


class TestCausalConv:
    @pytest.mark.parametrize(
        "B,S,K", [(1, 8, 2), (1, 12, 4), (2, 8, 4), (2, 12, 2), (3, 8, 2),
                  (3, 12, 4)]
    )
    def test_streaming_equivalence(self, B, S, K):
        """Full-sequence conv == token-by-token conv with carried state."""
        C = 6
        p = init_causal_conv(jax.random.key(0), C, K, jnp.float32)
        x = jnp.asarray(RNG.normal(size=(B, S, C)), jnp.float32)
        full, _ = apply_causal_conv(p, x)
        state = jnp.zeros((B, K - 1, C), jnp.float32)
        outs = []
        for t in range(S):
            y, state = apply_causal_conv(p, x[:, t : t + 1], state)
            outs.append(y)
        np.testing.assert_allclose(
            full, jnp.concatenate(outs, axis=1), atol=1e-5, rtol=1e-5
        )


class TestScansMatchRefs:
    def test_mamba_mix_chunking_invariant(self):
        """The chunked selective scan is chunk-size invariant."""
        from repro.models.ssm import mamba_mix

        cfg = reduced_config(get_config("falcon-mamba-7b"))
        from repro.models.ssm import init_mamba

        p = init_mamba(jax.random.key(0), cfg)
        u = jnp.asarray(RNG.normal(size=(2, 24, cfg.d_model)), jnp.float32)
        y1 = mamba_mix(p, u, cfg, chunk=4)
        y2 = mamba_mix(p, u, cfg, chunk=24)
        np.testing.assert_allclose(np.asarray(y1, np.float32),
                                   np.asarray(y2, np.float32),
                                   atol=5e-3, rtol=5e-3)

    def test_rglru_assoc_scan_matches_sequential(self):
        from repro.kernels.rglru_scan.ref import rglru_scan_ref
        from repro.models.rglru import rglru_scan as assoc_scan

        B, S, D = 2, 16, 8
        a = jnp.asarray(RNG.uniform(0.8, 0.99, size=(B, S, D)), jnp.float32)
        bx = jnp.asarray(RNG.normal(size=(B, S, D)), jnp.float32)
        h0 = jnp.asarray(RNG.normal(size=(B, D)), jnp.float32)
        # models.rglru.rglru_scan takes gate params; test combine directly
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        bx0 = bx.at[:, 0].add(a[:, 0] * h0)
        _, hs = jax.lax.associative_scan(combine, (a, bx0), axis=1)
        want = rglru_scan_ref(a, bx, h0)
        np.testing.assert_allclose(hs, want, atol=1e-5, rtol=1e-5)


class TestRope:
    @pytest.mark.parametrize("pos", [0, 1, 7, 63, 128, 511, 1000])
    def test_rope_is_rotation(self, pos):
        """|rope(x)| == |x| (pairwise rotations preserve norm)."""
        x = jnp.asarray(RNG.normal(size=(1, 2, 4, 16)), jnp.float32)
        p = jnp.full((4,), pos, jnp.int32)
        y = apply_rope(x, p, 10_000.0)
        np.testing.assert_allclose(
            jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1),
            atol=1e-4, rtol=1e-4,
        )

    def test_rope_relative_property(self):
        """<rope_m(q), rope_n(k)> depends only on m - n."""
        q = jnp.asarray(RNG.normal(size=(1, 1, 1, 32)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(1, 1, 1, 32)), jnp.float32)

        def dot_at(m, n):
            qm = apply_rope(q, jnp.array([m], jnp.int32), 1e4)
            kn = apply_rope(k, jnp.array([n], jnp.int32), 1e4)
            return float((qm * kn).sum())

        assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), rel=1e-4)
        assert dot_at(7, 0) == pytest.approx(dot_at(107, 100), rel=1e-4)
