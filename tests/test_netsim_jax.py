"""Batched JAX fluid engine: numpy-oracle parity + physical invariants.

The contract under test: `fluid_jax._slice_step` implements *identical*
math to `fluid.rotor_slice_step`, so the two engines must agree on every
emitted statistic (float32 vs float64 is the only divergence), and both
must honor byte conservation, a non-negative bandwidth tax, and a
monotone finished fraction on any Opera config.
"""
import numpy as np
import pytest

from repro.configs.opera_paper import OperaNetConfig
from repro.core.topology import build_opera_topology
from repro.netsim.fluid import rotor_slice_step, simulate_rotor_bulk
from repro.netsim.fluid_jax import (
    simulate_rotor_bulk_batch,
    simulate_rotor_bulk_jax,
)
from repro.netsim.sweep import (
    DesignPoint,
    SweepSpec,
    run_design,
    scenario_demand,
)
from repro.netsim.workloads import (
    demand_all_to_all,
    demand_hotrack,
    demand_permutation,
    demand_skew,
)

TINY = OperaNetConfig(name="tiny-32", k=4, num_racks=8, hosts_per_rack=2,
                      num_circuit_switches=2)


@pytest.fixture(scope="module")
def topo():
    return build_opera_topology(TINY.num_racks, TINY.u, seed=0)


def _demands():
    return {
        "shuffle": demand_all_to_all(8, 2, 1e6),
        "permutation": demand_permutation(8, 2, 5e7, seed=3),
        "skew": demand_skew(8, 2, 2e7, active_frac=0.4, seed=1),
        "hotrack": demand_hotrack(8, 2, 3e7),
    }


class TestParity:
    @pytest.mark.parametrize("vlb", [False, True])
    @pytest.mark.parametrize("workload", list(_demands()))
    def test_matches_numpy_oracle(self, topo, vlb, workload):
        d = _demands()[workload]
        a = simulate_rotor_bulk(TINY, d, vlb=vlb, max_cycles=200, topo=topo)
        b = simulate_rotor_bulk_jax(TINY, d, vlb=vlb, max_cycles=200,
                                    topo=topo)
        assert a.slices_run == b.slices_run
        assert np.isclose(a.fct_mean_ms, b.fct_mean_ms, rtol=1e-4)
        if np.isfinite(a.fct_99_ms):
            assert np.isclose(a.fct_99_ms, b.fct_99_ms, rtol=1e-4)
        else:
            assert not np.isfinite(b.fct_99_ms)
        assert np.isclose(a.throughput_gbps, b.throughput_gbps, rtol=1e-4)
        assert np.isclose(a.goodput_bytes, b.goodput_bytes, rtol=1e-4)
        assert np.isclose(a.wire_bytes, b.wire_bytes, rtol=1e-4)
        assert np.isclose(a.bandwidth_tax, b.bandwidth_tax, atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(a.finished_frac),
            np.asarray(b.finished_frac),
            atol=1e-5,
        )

    def test_single_step_lockstep(self, topo):
        """One raw slice step, numpy vs jnp, element-exact tolerances."""
        import jax.numpy as jnp

        from repro.netsim.fluid_jax import _slice_step

        rng = np.random.default_rng(0)
        n = TINY.num_racks
        own = rng.uniform(0, 5.0, (n, n))
        np.fill_diagonal(own, 0.0)
        relay = rng.uniform(0, 2.0, (n, n))
        np.fill_diagonal(relay, 0.0)
        adj = topo.matching_tensor()[2].astype(np.float64)
        o_np, r_np, delivered, moved = rotor_slice_step(
            own.copy(), relay.copy(), adj, vlb=True
        )
        state = (jnp.asarray(own), jnp.asarray(relay),
                 jnp.zeros(()), jnp.zeros(()))
        (o_jx, r_jx, done, wire), _ = _slice_step(
            state, jnp.asarray(adj), vlb=True
        )
        np.testing.assert_allclose(o_np, np.asarray(o_jx), atol=1e-5)
        np.testing.assert_allclose(r_np, np.asarray(r_jx), atol=1e-5)
        assert np.isclose(delivered, float(done), rtol=1e-6)
        assert np.isclose(delivered + moved, float(wire), rtol=1e-6)


class TestInvariants:
    @pytest.mark.parametrize("vlb", [False, True])
    def test_byte_conservation(self, topo, vlb):
        d = _demands()["permutation"]
        r = simulate_rotor_bulk_batch(TINY, d, vlb=vlb, max_cycles=50,
                                      topo=topo)
        # delivered + still-queued == offered, at scan end
        end_done = r.finished_frac[0, -1] * r.total_bytes[0]
        np.testing.assert_allclose(
            end_done + r.residual_bytes[0], r.total_bytes[0], rtol=1e-5
        )

    def test_finished_frac_monotone_and_bounded(self, topo):
        for name, d in _demands().items():
            r = simulate_rotor_bulk_batch(TINY, d, vlb=True, max_cycles=100,
                                          topo=topo)
            f = r.finished_frac[0]
            assert (np.diff(f) >= -1e-6).all(), name
            assert f[-1] <= 1.0 + 1e-5, name

    def test_bandwidth_tax_nonnegative_and_zero_without_vlb(self, topo):
        for d in _demands().values():
            direct = simulate_rotor_bulk_batch(TINY, d, vlb=False,
                                               max_cycles=100, topo=topo)
            with_vlb = simulate_rotor_bulk_batch(TINY, d, vlb=True,
                                                 max_cycles=100, topo=topo)
            assert abs(direct.bandwidth_tax[0]) < 1e-5   # one-hop only
            assert with_vlb.bandwidth_tax[0] >= -1e-6

    def test_vlb_helps_skew_and_costs_at_most_a_cycle(self, topo):
        """Relaying may defer the last trickle by a relay-circuit wait
        (bounded by one cycle) but must strictly speed skewed demand."""
        for name, d in _demands().items():
            a = simulate_rotor_bulk_batch(TINY, d, vlb=False, max_cycles=200,
                                          topo=topo)
            b = simulate_rotor_bulk_batch(TINY, d, vlb=True, max_cycles=200,
                                          topo=topo)
            assert b.slices_run[0] <= a.slices_run[0] + topo.num_slices, name
            if name in ("permutation", "skew", "hotrack"):
                assert b.slices_run[0] < a.slices_run[0], name


class TestBatching:
    def test_16_scenarios_single_vmapped_call(self, topo):
        """The acceptance-bar batch: a (workload x load x seed) grid of 16
        scenarios through one vmapped call, each row matching its
        individually-simulated numpy oracle."""
        base = _demands()
        demands = np.stack(
            [base[w] * s
             for w in ("shuffle", "permutation", "skew", "hotrack")
             for s in (0.5, 1.0, 2.0, 4.0)]
        )
        assert demands.shape[0] == 16
        r = simulate_rotor_bulk_batch(TINY, demands, vlb=True,
                                      max_cycles=150, topo=topo)
        assert r.batch_size == 16
        # spot-check rows against the oracle (full parity is TestParity)
        for i in (0, 5, 10, 15):
            o = simulate_rotor_bulk(TINY, demands[i], vlb=True,
                                    max_cycles=150, topo=topo)
            assert o.slices_run == int(r.slices_run[i])
            assert np.isclose(o.throughput_gbps, r.throughput_gbps[i],
                              rtol=1e-4)
            assert np.isclose(o.fct_mean_ms, r.fct_mean_ms[i], rtol=1e-4)

    def test_batch_rows_independent(self, topo):
        """vmap must not couple scenarios: a row's result is identical
        whether simulated alone or inside a batch."""
        d = _demands()["skew"]
        alone = simulate_rotor_bulk_batch(TINY, d, vlb=True, max_cycles=60,
                                          topo=topo)
        batch = simulate_rotor_bulk_batch(
            TINY, np.stack([d * 3.0, d, d * 0.1]), vlb=True, max_cycles=60,
            topo=topo,
        )
        np.testing.assert_allclose(
            alone.finished_frac[0], batch.finished_frac[1], atol=1e-6
        )


class TestSweep:
    def test_run_design_grid(self):
        spec = SweepSpec(
            designs=(DesignPoint(k=4, num_racks=8),),
            workloads=("shuffle", "permutation"),
            loads=(0.2, 0.5),
            seeds=(0, 1),
            max_cycles=60,
        )
        rows, res = run_design(spec, spec.designs[0])
        assert len(rows) == 8 and res.batch_size == 8
        for r in rows:
            assert r["finished_frac"] >= 0.999
            assert r["bandwidth_tax"] >= -1e-6
            assert 0.0 < r["throughput_frac"] <= 1.0

    def test_scenario_demand_offers_requested_load(self):
        cfg = DesignPoint(k=4, num_racks=8).to_config()
        from repro.core.schedule import cycle_timing

        cyc_s = cycle_timing(cfg).cycle_ms * 1e-3
        per_host = 0.3 * cfg.link_rate_gbps * 1e9 / 8 * cyc_s
        for w in ("shuffle", "permutation"):
            d = scenario_demand(w, cfg, 0.3, seed=0)
            # every active rack offers ~ hosts_per_rack * per_host bytes
            out = d.sum(1)
            active = out[out > 0]
            np.testing.assert_allclose(
                active, cfg.hosts_per_rack * per_host, rtol=1e-6
            )
