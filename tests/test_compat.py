"""Unit tests for the repro.compat shim itself (ROADMAP jax compat policy).

Asserts the modern->legacy kwarg mapping (`check_vma`->`check_rep`,
`axis_names`->`auto`, ambient-mesh resolution) and that the
`HAS_PARTIAL_MANUAL` gate degrades the rotor pod-sync trainer without
changing the update math.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat

legacy_only = pytest.mark.skipif(
    compat.HAS_NATIVE_SHARD_MAP,
    reason="legacy kwarg mapping only exists on jax 0.4.x",
)


@pytest.fixture()
def mesh():
    return compat.make_mesh((1, 1), ("data", "model"))


class TestShardMapKwargMapping:
    @pytest.fixture()
    def captured(self, monkeypatch):
        """Intercept the legacy shard_map and record the mapped kwargs."""
        calls = {}

        def fake(f, mesh, in_specs, out_specs, check_rep, auto):
            calls.update(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                         check_rep=check_rep, auto=auto)
            return f

        monkeypatch.setattr(compat, "_legacy_shard_map", fake)
        return calls

    @legacy_only
    def test_check_vma_maps_to_check_rep(self, mesh, captured):
        compat.shard_map(lambda x: x, mesh, in_specs=P(), out_specs=P(),
                         check_vma=True)
        assert captured["check_rep"] is True
        assert captured["auto"] == frozenset()
        compat.shard_map(lambda x: x, mesh, in_specs=P(), out_specs=P(),
                         check_vma=False)
        assert captured["check_rep"] is False

    @legacy_only
    def test_axis_names_maps_to_auto_complement(self, mesh, captured):
        compat.shard_map(lambda x: x, mesh, in_specs=P(), out_specs=P(),
                         axis_names={"data"})
        assert captured["auto"] == frozenset({"model"})
        # partial-manual cannot check replication on 0.4.x: check_rep is
        # forced off whenever auto is nonempty, even with check_vma=True
        assert captured["check_rep"] is False

    @legacy_only
    def test_full_axis_names_keeps_check_rep(self, mesh, captured):
        compat.shard_map(lambda x: x, mesh, in_specs=P(), out_specs=P(),
                         check_vma=True, axis_names={"data", "model"})
        assert captured["auto"] == frozenset()
        assert captured["check_rep"] is True

    @legacy_only
    def test_ambient_mesh_resolution(self, mesh, captured):
        with compat.set_mesh(mesh):
            compat.shard_map(lambda x: x, in_specs=P(), out_specs=P())
        assert captured["mesh"] is mesh

    @legacy_only
    def test_no_mesh_no_ambient_raises(self):
        with pytest.raises(ValueError, match="ambient mesh"):
            compat.shard_map(lambda x: x, in_specs=P(), out_specs=P())


class TestShardMapExecutes:
    def test_full_manual_matches_reference(self, mesh):
        x = jnp.arange(8.0).reshape(2, 4)
        with compat.set_mesh(mesh):
            f = compat.shard_map(
                lambda a: a * 2.0, mesh,
                in_specs=P("data", None), out_specs=P("data", None),
                check_vma=False,
            )
            np.testing.assert_allclose(np.asarray(jax.jit(f)(x)),
                                       np.asarray(x) * 2.0)

    def test_axis_size_inside_region(self, mesh):
        def body(a):
            return a * compat.axis_size("data")

        with compat.set_mesh(mesh):
            f = compat.shard_map(body, mesh, in_specs=P("data", None),
                                 out_specs=P("data", None), check_vma=False)
            out = jax.jit(f)(jnp.ones((2, 2)))
        np.testing.assert_allclose(np.asarray(out), 1.0)


class TestMakeMesh:
    def test_axes_and_shape(self):
        m = compat.make_mesh((1, 1), ("data", "model"))
        assert m.axis_names == ("data", "model")
        assert dict(m.shape) == {"data": 1, "model": 1}

    def test_set_mesh_context_installs_ambient(self):
        m = compat.make_mesh((1,), ("d",))
        with compat.set_mesh(m):
            from jax._src import mesh as mesh_lib

            assert mesh_lib.thread_resources.env.physical_mesh is m


class TestPartialManualGate:
    def test_rotor_grad_sync_degrades_without_changing_update(self):
        """With HAS_PARTIAL_MANUAL False (jax 0.4.x), grad_sync='rotor'
        must fall back to the GSPMD path: same params, same metrics as
        grad_sync='xla' after a train step."""
        from repro.configs import get_config
        from repro.configs.base import reduced_config
        from repro.data.pipeline import SyntheticLM
        from repro.launch.mesh import pctx_for_mesh
        from repro.models import init_params
        from repro.optim.adamw import AdamWConfig
        from repro.train import trainer as trainer_mod
        from repro.train.trainer import init_train_state, make_train_step

        base = reduced_config(get_config("smollm-360m")).replace(
            num_layers=1, vocab_size=64)
        params = init_params(base, jax.random.key(0))
        opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=4)
        src = SyntheticLM(base.vocab_size, 8, 4, seed=0)
        batch = jax.tree.map(jnp.asarray, src.batch_at(0))
        mesh = compat.make_mesh((1, 1, 1), ("pod", "data", "model"))
        pctx = pctx_for_mesh(mesh)
        assert pctx.pod_axis == "pod"

        outs = {}
        for sync in ("xla", "rotor"):
            cfg = base.replace(grad_sync=sync)
            with compat.set_mesh(mesh):
                state = init_train_state(cfg, params)
                step = jax.jit(make_train_step(cfg, pctx, opt))
                outs[sync] = step(state, batch)

        if not trainer_mod.HAS_PARTIAL_MANUAL:
            # both configs must have taken the identical GSPMD path
            (s1, m1), (s2, m2) = outs["xla"], outs["rotor"]
            assert float(m1["loss"]) == pytest.approx(float(m2["loss"]),
                                                      rel=1e-6)
            for a, b in zip(jax.tree.leaves(s1["params"]),
                            jax.tree.leaves(s2["params"])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
