"""Fleet health: failure detection, straggler policy, restart planning."""
import pytest

from repro.train.health import FleetMonitor, HealthConfig, RestartPlan


def _fleet(n=8):
    return FleetMonitor([f"w{i}" for i in range(n)],
                        HealthConfig(timeout_steps=3, straggler_factor=2.0,
                                     patience=3))


class TestDetection:
    def test_healthy_fleet_no_alarms(self):
        m = _fleet()
        for step in range(1, 6):
            for w in list(m.workers):
                m.heartbeat(w, step, 1.0)
            r = m.check(step)
            assert not r["dead"] and not r["stragglers"]

    def test_dead_worker_detected_after_timeout(self):
        m = _fleet()
        for step in range(1, 6):
            for w in list(m.workers):
                if w != "w3" or step < 2:
                    m.heartbeat(w, step, 1.0)
            r = m.check(step)
            if step < 4:
                assert "w3" not in r["dead"]
        assert "w3" in m.failed

    def test_straggler_needs_patience(self):
        m = _fleet()
        flagged_at = None
        for step in range(1, 10):
            for w in list(m.workers):
                m.heartbeat(w, step, 5.0 if w == "w1" else 1.0)
            r = m.check(step)
            if "w1" in r["stragglers"]:
                flagged_at = step
                break
        assert flagged_at is not None and flagged_at >= 3

    def test_transient_slowness_forgiven(self):
        m = _fleet()
        for step in range(1, 10):
            slow = step == 3  # one slow step only
            for w in list(m.workers):
                m.heartbeat(w, step, 5.0 if (w == "w1" and slow) else 1.0)
            r = m.check(step)
            assert "w1" not in r["stragglers"]
        assert "w1" not in m.failed


class TestRestartPlan:
    def test_shrinks_data_axis_keeps_model_axis(self):
        m = _fleet(8)
        m.failed = {"w6", "w7"}
        plan = RestartPlan.from_failure(
            m, latest_ckpt_step=400, devices_per_worker=8, model_axis=16
        )
        assert plan.restore_step == 400
        assert plan.new_mesh_shape[1] == 16
        assert plan.new_mesh_shape[0] == (6 * 8) // 16
        assert len(plan.surviving_workers) == 6


class TestEndToEndDrill:
    def test_detect_then_restore_then_resume(self, tmp_path):
        """The full control-plane loop against a real (tiny) train run."""
        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.configs import get_config
        from repro.configs.base import reduced_config
        from repro.data.pipeline import SyntheticLM
        from repro.models import init_params
        from repro.models.parallel import single_device_ctx
        from repro.optim.adamw import AdamWConfig
        from repro.train.checkpoint import Checkpointer
        from repro.train.trainer import init_train_state, make_train_step

        cfg = reduced_config(get_config("smollm-360m")).replace(
            num_layers=2, vocab_size=64
        )
        params = init_params(cfg, jax.random.key(0))
        opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=12)
        step_fn = jax.jit(make_train_step(cfg, single_device_ctx(), opt))
        src = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)
        ck = Checkpointer(str(tmp_path))
        mon = _fleet(4)

        state = init_train_state(cfg, params)
        crashed_at = None
        for i in range(12):
            state, _ = step_fn(state, jax.tree.map(jnp.asarray, src.batch_at(i)))
            for w in list(mon.workers):
                if w == "w2" and i >= 6:
                    continue  # w2 dies at step 6
                mon.heartbeat(w, i + 1, 1.0)
            if (i + 1) % 4 == 0:
                ck.save(i + 1, state, blocking=True)
            if mon.check(i + 1)["dead"]:
                crashed_at = i + 1
                break
        assert crashed_at is not None

        plan = RestartPlan.from_failure(
            mon, ck.latest_step(), devices_per_worker=1, model_axis=1
        )
        state2, start = ck.restore(state, step=plan.restore_step)
        assert start <= crashed_at
        for i in range(start, 12):  # resume deterministically (data by step)
            state2, m = step_fn(
                state2, jax.tree.map(jnp.asarray, src.batch_at(i))
            )
        assert np.isfinite(float(m["loss"]))
