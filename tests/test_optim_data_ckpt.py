"""Optimizer, data pipeline, checkpoint/elastic-restore tests."""
import os

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import (
    AdamWConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    lr_at,
)
from repro.train.checkpoint import Checkpointer


class TestAdamW:
    def _params(self):
        return {
            "w": jnp.ones((4, 4)) * 0.5,
            "ln": {"scale": jnp.ones((4,))},
        }

    def test_quadratic_converges(self):
        c = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                        weight_decay=0.0)
        params = {"w": jnp.array([3.0, -2.0])}
        st_ = init_opt_state(params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, st_, _ = adamw_update(c, params, grads, st_)
        assert float(jnp.abs(params["w"]).max()) < 0.15

    def test_clipping(self):
        c = AdamWConfig(clip_norm=1.0, warmup_steps=1)
        params = self._params()
        st_ = init_opt_state(params)
        grads = jax.tree.map(lambda p: 1e6 * jnp.ones_like(p), params)
        _, _, m = adamw_update(c, params, grads, st_)
        assert float(m["grad_norm"]) > 1e5  # reported pre-clip

    def test_no_decay_on_norm_scales(self):
        c = AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=1)
        params = self._params()
        st_ = init_opt_state(params)
        zero_g = jax.tree.map(jnp.zeros_like, params)
        new, _, _ = adamw_update(c, params, zero_g, st_)
        # zero grads: decayed params shrink, norm scales must not
        assert float(new["w"].mean()) < 0.5
        np.testing.assert_allclose(new["ln"]["scale"], params["ln"]["scale"])

    @pytest.mark.parametrize(
        "step", [0, 1, 50, 99, 100, 101, 500, 5000, 9999, 10_000, 13_337,
                 20_000]
    )
    def test_lr_schedule_bounds(self, step):
        c = AdamWConfig(lr=3e-4, warmup_steps=100, total_steps=10_000)
        lr = float(lr_at(c, jnp.asarray(step)))
        # lr_at computes in float32: allow one ulp of representation slack
        assert 0.0 < lr <= c.lr * (1 + 1e-6)
        if step >= c.total_steps:
            assert lr == pytest.approx(c.lr * c.min_lr_frac, rel=1e-3)


class TestPipeline:
    def test_deterministic_and_resumable(self):
        src = SyntheticLM(128, 16, 4, seed=7)
        a = src.batch_at(13)
        b = SyntheticLM(128, 16, 4, seed=7).batch_at(13)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_targets_are_next_tokens(self):
        src = SyntheticLM(128, 16, 4, seed=7)
        b = src.batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])

    def test_learnable_structure(self):
        src = SyntheticLM(64, 512, 8, seed=0)
        b = src.batch_at(0)
        # successors constrained: conditional entropy well below ln(V)
        assert src.conditional_entropy() < 0.7 * np.log(64)


class TestCheckpoint:
    def _state(self, scale=1.0):
        return {
            "params": {"w": jnp.arange(12.0).reshape(3, 4) * scale,
                       "b": jnp.ones((4,)) * scale},
            "opt": {"step": jnp.asarray(5, jnp.int32)},
        }

    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        state = self._state()
        ck.save(100, state, blocking=True)
        restored, step = ck.restore(state)
        assert step == 100
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(a, b), state, restored
        )

    def test_keep_last_k(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, self._state(s), blocking=True)
        assert ck.steps() == [3, 4]

    def test_elastic_restore_onto_mesh(self, tmp_path):
        """Save unsharded, restore onto an explicit (1,1) mesh sharding —
        the elastic-resize path (mesh-shape-independent checkpoint)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        ck = Checkpointer(str(tmp_path))
        state = self._state()
        ck.save(7, state, blocking=True)
        mesh = compat.make_mesh((1, 1), ("data", "model"))
        shardings = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), state
        )
        restored, step = ck.restore(state, shardings=shardings)
        assert step == 7
        assert restored["params"]["w"].sharding.mesh.shape == {"data": 1,
                                                               "model": 1}

    def test_async_save_then_wait(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(3, self._state(), blocking=False)
        ck.wait()
        assert ck.latest_step() == 3


class TestGlobalNorm:
    @pytest.mark.parametrize("s", [0.1, 0.5, 1.0, 2.0, 3.7, 25.0, 100.0])
    def test_scaling_property(self, s):
        t = {"a": jnp.ones((3,)), "b": jnp.full((2, 2), 2.0)}
        n1 = float(global_norm(t))
        n2 = float(global_norm(jax.tree.map(lambda x: x * s, t)))
        assert n2 == pytest.approx(n1 * s, rel=1e-4)
