"""Permutation-sparse rotor slice engine: index-tensor structure, kernel
trio parity, and sparse-vs-dense full-engine agreement.

Three contracts under test:

  1. `OperaTopology.matching_index_tensor()` is a lossless re-encoding of
     `matching_tensor()`: scattering ones along (i, dst[i, s]) rebuilds
     the dense adjacency exactly, every live entry is an involution, and
     grouped reconfiguration darkens (at least) `groups` whole columns
     per slice.
  2. The `kernels/rotor_slice` trio agrees with itself (Pallas
     interpret path vs jnp ref path, bitwise — same jitted expression
     graph) and with the numpy oracle `fluid.rotor_slice_step`.
  3. The sparse batch drivers (`_run_batch_sparse`, and the faulted
     engine behind ``engine="sparse"``) match the dense scan engine on
     full trajectories, unfaulted and under a nonempty
     `FailureSchedule`.
"""
import numpy as np
import pytest

from repro.core.schedule import cycle_timing, slice_capacity_bytes
from repro.core.topology import build_opera_topology
from repro.netsim.faults import FailureEvent, FailureSchedule
from repro.netsim.fluid import rotor_slice_step as oracle_step
from repro.netsim.fluid_jax import simulate_rotor_bulk_batch
from repro.netsim.sweep import DesignPoint, scenario_demand

# the default Appendix-B design points staticcheck verifies (k, n, groups)
DESIGNS = [(12, 108, 1), (12, 108, 2), (8, 16, 1)]


def _topo(k, n, g):
    return build_opera_topology(n, k // 2, seed=0, groups=g)


# ---------------------------------------------------------------------------
# 1. index tensor <-> dense tensor round trip + structure
# ---------------------------------------------------------------------------


class TestIndexTensor:
    @pytest.mark.parametrize("k,n,g", DESIGNS)
    def test_round_trip_reconstructs_dense(self, k, n, g):
        topo = _topo(k, n, g)
        dst = topo.matching_index_tensor()
        dense = topo.matching_tensor()
        assert dst.dtype == np.int32
        assert dst.shape == (topo.num_slices, n, topo.num_switches)
        rebuilt = np.zeros_like(dense)
        t, i, s = np.nonzero(dst < n)
        rebuilt[t, i, dst[t, i, s]] = 1.0
        np.testing.assert_array_equal(rebuilt, dense)

    @pytest.mark.parametrize("k,n,g", DESIGNS)
    def test_live_entries_are_involutions(self, k, n, g):
        dst = _topo(k, n, g).matching_index_tensor()
        i = np.arange(n)
        for t in range(dst.shape[0]):
            for s in range(dst.shape[2]):
                col = dst[t, :, s]
                live = col < n
                # dst[dst[i, s], s] == i and no self-maps survive export
                assert np.array_equal(col[col[live]], i[live])
                assert not np.any(col[live] == i[live])

    @pytest.mark.parametrize("k,n,g", DESIGNS + [(8, 16, 2)])
    def test_dark_columns_cover_reconfiguring_group(self, k, n, g):
        """Each slice darkens whole columns for the `groups` switches
        mid-reconfiguration (all-sentinel); matchings that merely hold
        self-loops produce partial sentinels, never a short column."""
        dst = _topo(k, n, g).matching_index_tensor()
        for t in range(dst.shape[0]):
            fully_dark = int((dst[t] == n).all(axis=0).sum())
            assert fully_dark >= g, (t, fully_dark)

    def test_sentinel_marks_self_loops(self):
        """At k8-n16 some live matchings hold fixed points: the sentinel
        lands exactly where the dense adjacency row has no circuit on
        that switch's matching."""
        topo = _topo(8, 16, 1)
        dst = topo.matching_index_tensor()
        dense = topo.matching_tensor()
        # rows with a sentinel in a live (not fully-dark) column have
        # one fewer live circuit than fully-live rows
        for t in range(dst.shape[0]):
            live_cols = ~(dst[t] == 16).all(axis=0)
            row_live = (dst[t][:, live_cols] < 16).sum(axis=1)
            np.testing.assert_array_equal(row_live, dense[t].sum(axis=1))


# ---------------------------------------------------------------------------
# 2. kernel trio parity: Pallas interpret vs ref path vs numpy oracle
# ---------------------------------------------------------------------------


class TestKernelParity:
    @pytest.fixture(scope="class")
    def state(self):
        topo = _topo(8, 16, 1)
        dst = topo.matching_index_tensor()
        dense = topo.matching_tensor()
        rng = np.random.default_rng(0)
        own = rng.uniform(0.0, 2.0, (3, 16, 16)).astype(np.float32)
        relay = rng.uniform(0.0, 1.0, (3, 16, 16)).astype(np.float32)
        for a in (own, relay):
            a[:, np.arange(16), np.arange(16)] = 0.0
        return dst, dense, own, relay

    @pytest.mark.parametrize("vlb", [False, True])
    @pytest.mark.parametrize("t", [0, 3, 7])
    def test_pallas_kernel_bitwise_matches_ref_path(self, state, vlb, t):
        import jax.numpy as jnp

        from repro.kernels.rotor_slice import rotor_slice_step

        dst, _, own, relay = state
        own_j, relay_j = jnp.asarray(own), jnp.asarray(relay)
        dst_j = jnp.asarray(dst[t])
        ref = rotor_slice_step(own_j, relay_j, dst_j, vlb=vlb)
        pal = rotor_slice_step(own_j, relay_j, dst_j, vlb=vlb,
                               force_pallas=True)
        for a, b in zip(ref, pal):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("vlb", [False, True])
    @pytest.mark.parametrize("t", [0, 3, 7])
    def test_op_matches_numpy_oracle(self, state, vlb, t):
        import jax.numpy as jnp

        from repro.kernels.rotor_slice import rotor_slice_step

        dst, dense, own, relay = state
        o2, r2, deliv, moved = rotor_slice_step(
            jnp.asarray(own), jnp.asarray(relay), jnp.asarray(dst[t]),
            vlb=vlb)
        for b in range(own.shape[0]):
            eo, er, ed, em = oracle_step(
                own[b].astype(np.float64), relay[b].astype(np.float64),
                dense[t].astype(np.float64), vlb=vlb)
            np.testing.assert_allclose(np.asarray(o2[b]), eo, atol=1e-5)
            np.testing.assert_allclose(np.asarray(r2[b]), er, atol=1e-5)
            assert np.isclose(float(deliv[b]), ed, atol=1e-4)
            assert np.isclose(float(moved[b]), em, atol=1e-4)


# ---------------------------------------------------------------------------
# 3. full-engine parity: sparse vs dense batch drivers
# ---------------------------------------------------------------------------

DP = DesignPoint(k=8, num_racks=16, groups=1)
DP_G2 = DesignPoint(k=8, num_racks=16, groups=2)


def _drift(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(a), 1.0)))


class TestEngineParity:
    def test_run_batch_trajectories_agree(self):
        """Unfaulted drivers on an overloaded skew batch: cumulative
        delivered/wire trajectories and residuals must agree slice by
        slice, not just in the totals."""
        import jax.numpy as jnp

        from repro.netsim.fluid_jax import _run_batch, _run_batch_sparse

        cfg = DP.to_config()
        topo = build_opera_topology(cfg.num_racks, cfg.u, seed=0)
        cap = slice_capacity_bytes(cfg, cycle_timing(cfg))
        dem = np.stack([scenario_demand("skew", cfg, 2.5, s)
                        for s in range(3)])
        own0 = jnp.asarray(dem / cap, jnp.float32)
        dense = _run_batch(jnp.asarray(topo.matching_tensor()), own0, True, 4)
        sparse = _run_batch_sparse(
            jnp.asarray(topo.matching_index_tensor()), own0, True, 4)
        assert np.asarray(dense[2]).max() > 0, "skew batch must not drain"
        for d, s in zip(dense, sparse):
            assert _drift(d, s) < 1e-5

    @pytest.mark.parametrize("dp", [DP, DP_G2], ids=["g1", "g2"])
    @pytest.mark.parametrize("vlb", [False, True])
    def test_faulted_engines_agree(self, dp, vlb):
        cfg = dp.to_config()
        topo = build_opera_topology(
            cfg.num_racks, cfg.u, seed=0, groups=cfg.groups)
        faults = FailureSchedule(
            num_racks=cfg.num_racks, num_switches=cfg.u,
            events=(FailureEvent("link", ((1, 0), (5, 1)), onset_step=1,
                                 detect_lag=2, recover_step=10),
                    FailureEvent("tor", (3,), onset_step=2,
                                 detect_lag=1, recover_step=12)))
        dem = np.stack([scenario_demand("permutation", cfg, 0.5, s)
                        for s in range(2)])
        res = {
            engine: simulate_rotor_bulk_batch(
                cfg, dem, vlb=vlb, max_cycles=10, topo=topo,
                faults=faults, engine=engine)
            for engine in ("dense", "sparse")
        }
        for field in ("goodput_bytes", "wire_bytes", "residual_bytes"):
            d = getattr(res["dense"], field)
            s = getattr(res["sparse"], field)
            assert _drift(d, s) < 1e-5, field
        # blackholed is a small difference of large attempted/delivered
        # totals: normalize by total offered bytes, not by itself
        bh_d = np.asarray(res["dense"].blackholed_bytes)
        bh_s = np.asarray(res["sparse"].blackholed_bytes)
        if vlb:   # VLB spread commits bytes to every edge, lag included
            assert bh_d.max() > 0, "schedule must blackhole something"
        total = dem.sum(axis=(1, 2))
        assert float(np.max(np.abs(bh_d - bh_s) / total)) < 1e-6

    def test_engine_dispatch_validates(self):
        from repro.netsim.fluid_jax import (
            SPARSE_AUTO_RACKS,
            resolve_engine,
        )

        assert resolve_engine("auto", SPARSE_AUTO_RACKS - 1) == "dense"
        assert resolve_engine("auto", SPARSE_AUTO_RACKS) == "sparse"
        assert resolve_engine("dense", 10_000) == "dense"
        assert resolve_engine("sparse", 8) == "sparse"
        with pytest.raises(ValueError):
            resolve_engine("turbo", 16)
