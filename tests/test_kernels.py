"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.mamba_scan import mamba_scan, mamba_scan_ref
from repro.kernels.moe_gmm import moe_gmm, moe_gmm_ref
from repro.kernels.rglru_scan import rglru_scan, rglru_scan_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-5
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Sk,hd,causal,window,bq,bk",
    [
        (1, 2, 2, 64, 64, 32, True, 0, 32, 32),     # MHA causal
        (2, 4, 2, 64, 64, 64, True, 0, 16, 32),     # GQA
        (1, 8, 1, 32, 32, 32, True, 0, 16, 16),     # MQA
        (1, 2, 2, 64, 64, 32, False, 0, 32, 32),    # bidirectional
        (1, 2, 1, 64, 64, 32, True, 24, 16, 16),    # sliding window
        (1, 2, 2, 32, 96, 32, True, 0, 16, 32),     # cross lens (decode-ish)
        (1, 3, 1, 48, 48, 16, True, 0, 16, 16),     # non-pow2 heads
    ],
)
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Sk, hd, causal, window,
                               bq, bk, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Hq, Sq, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Sk, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Sk, hd)), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,D,N,bd,bs",
    [
        (1, 16, 8, 4, 8, 8),
        (2, 32, 16, 4, 8, 16),
        (1, 24, 12, 2, 4, 8),      # non-pow2 dims
        (2, 16, 8, 8, 8, 4),
    ],
)
def test_mamba_scan_sweep(B, S, D, N, bd, bs, dtype):
    x = jnp.asarray(RNG.normal(size=(B, S, D)), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, S, D)), dtype)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), dtype)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), dtype)
    A = -jnp.exp(jnp.asarray(RNG.normal(size=(D, N)), jnp.float32))
    Dp = jnp.asarray(RNG.normal(size=(D,)), jnp.float32)
    got = mamba_scan(x, dt, Bm, Cm, A, Dp, block_d=bd, block_s=bs,
                     interpret=True)
    want = mamba_scan_ref(x, dt, Bm, Cm, A, Dp)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want, np.float32),
        **(_tol(dtype) if dtype == jnp.bfloat16 else dict(atol=1e-4, rtol=1e-4)),
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,D,bd,bs", [(1, 32, 16, 8, 8), (2, 64, 8, 8, 32), (1, 48, 24, 12, 16)]
)
def test_rglru_scan_sweep(B, S, D, bd, bs, dtype):
    a = jnp.asarray(RNG.uniform(0.7, 0.999, size=(B, S, D)), dtype)
    bx = jnp.asarray(RNG.normal(size=(B, S, D)), dtype)
    h0 = jnp.asarray(RNG.normal(size=(B, D)), jnp.float32)
    got = rglru_scan(a, bx, h0, block_d=bd, block_s=bs, interpret=True)
    want = rglru_scan_ref(a, bx, h0)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want),
        **(_tol(dtype) if dtype == jnp.bfloat16 else dict(atol=1e-4, rtol=1e-4)),
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "E,C,D,F,bc,bf",
    [(2, 16, 16, 32, 8, 16), (4, 8, 32, 64, 8, 32), (3, 12, 8, 24, 4, 8)],
)
def test_moe_gmm_sweep(E, C, D, F, bc, bf, dtype):
    h = jnp.asarray(RNG.normal(size=(E, C, D)), dtype)
    wg = jnp.asarray(RNG.normal(size=(E, D, F)) * 0.1, dtype)
    wu = jnp.asarray(RNG.normal(size=(E, D, F)) * 0.1, dtype)
    wd = jnp.asarray(RNG.normal(size=(E, F, D)) * 0.1, dtype)
    got = moe_gmm(h, wg, wu, wd, block_c=bc, block_f=bf, interpret=True)
    want = moe_gmm_ref(h, wg, wu, wd)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_flash_matches_model_chunked_attention():
    """Kernel vs the model-zoo XLA path (chunked_attention)."""
    from repro.models.attention import chunked_attention

    B, Hq, Hkv, S, hd = 1, 4, 2, 64, 32
    q = jnp.asarray(RNG.normal(size=(B, Hq, S, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, hd)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    a = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                        interpret=True)
    b = chunked_attention(q, k, v, pos, pos, causal=True, chunk_q=16,
                          chunk_k=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)
