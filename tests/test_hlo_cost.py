"""Loop-aware HLO cost model vs unrolled ground truth."""
import jax

from jax.sharding import PartitionSpec as P

from repro import compat
import jax.numpy as jnp
import pytest
from jax import lax

from repro.analysis.hlo import collective_bytes
from repro.analysis.hlo_cost import analyze


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


X = jax.ShapeDtypeStruct((128, 128), jnp.float32)


class TestLoopAwareness:
    def test_scan_matches_unroll(self):
        def f_scan(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = lax.scan(body, x, None, length=10)
            return y.sum()

        def f_unroll(x, w):
            for _ in range(10):
                x = jnp.tanh(x @ w)
            return x.sum()

        a = analyze(_compile(f_scan, X, X))
        b = analyze(_compile(f_unroll, X, X))
        assert a["flops"] == pytest.approx(b["flops"], rel=0.02)

    def test_nested_scan(self):
        def g(x, w):
            def outer(c, _):
                def inner(d, _):
                    return d @ w, None
                d, _ = lax.scan(inner, c, None, length=5)
                return d, None
            y, _ = lax.scan(outer, x, None, length=4)
            return y.sum()

        a = analyze(_compile(g, X, X))
        expect = 20 * 2 * 128**3
        assert a["flops"] == pytest.approx(expect, rel=0.02)

    def test_dot_flops_exact(self):
        def f(x, w):
            return (x @ w).sum()

        a = analyze(_compile(f, X, X))
        assert a["flops"] == pytest.approx(2 * 128**3, rel=0.02)

    def test_batch_dot(self):
        B = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
        W = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)

        def f(a, b):
            return jnp.einsum("bij,bjk->bik", a, b).sum()

        a = analyze(_compile(f, B, W))
        assert a["flops"] == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.05)

    def test_bytes_positive_and_bounded(self):
        def f(x, w):
            return (x @ w).sum()

        a = analyze(_compile(f, X, X))
        lo = 3 * 128 * 128 * 4          # operands + output once
        assert a["bytes"] >= lo
        assert a["bytes"] <= 20 * lo     # fusion slack


class TestCollectiveAccounting:
    def test_psum_inside_scan_multiplied(self):
        """Naive text grep counts loop collectives once; analyze() must
        multiply by trip count."""
        mesh = compat.make_mesh((1,), ("d",))

        def f(x):
            def per(a):
                def body(c, _):
                    return lax.psum(c, "d") * 0.5, None
                y, _ = lax.scan(body, a, None, length=7)
                return y
            return compat.shard_map(
                per, mesh=mesh, in_specs=P("d"), out_specs=P("d"),
                check_vma=False,
            )(x)

        spec = jax.ShapeDtypeStruct((4, 8), jnp.float32)
        hlo = jax.jit(f).lower(spec).compile().as_text()
        la = analyze(hlo)
        naive = collective_bytes(hlo)
        if naive["count_total"] > 0:  # CPU may elide 1-device collectives
            assert la["coll_count_total"] >= 7 * naive["count_total"] * 0.9
