"""Batched JAX flow engine: numpy-oracle lockstep + stability classification.

The contract under test: `flows_jax._flow_step` implements *identical*
per-step math to `flows._oracle_steps` (both consume the same
`FlowScenario` and the same `flows.finalize`), so the two engines must
agree per step on remaining bytes (float32 vs float64 is the only
divergence) and on every emitted statistic; batching must not couple
scenarios; and the admission classifier must produce the paper's
saturation ordering on small grids.
"""
import numpy as np
import pytest

from repro.netsim import flows
from repro.netsim.flows import (
    build_mixed_scenario,
    build_scenario,
    finalize,
    saturation_load,
)
from repro.netsim.flows_jax import (
    saturation_ladder,
    simulate_flows_batch,
    simulate_grid,
)

# small enough that the full parity grid runs in seconds, large enough
# that each scenario holds a few hundred flows
TINY = dict(num_hosts=16, horizon_s=0.12, dt_s=5e-4, tail_s=0.1)


def _scenarios():
    return [
        build_scenario(net, wl, load, seed=3, **TINY)
        for net in ("opera", "expander", "clos", "rotornet")
        for wl in ("datamining", "websearch")
        for load in (0.05, 0.3)
    ]


class TestParity:
    def test_per_step_remaining_lockstep(self):
        """Every scenario's full remaining-bytes trajectory, numpy
        oracle vs vmapped scan, at float32 tolerance."""
        scns = _scenarios()
        batch = simulate_flows_batch(scns, trace=True)
        for s, tr in zip(scns, batch.traces):
            _, _, _, _, oracle_tr = flows._oracle_steps(s, trace=True)
            assert oracle_tr.shape == tr.shape
            np.testing.assert_allclose(
                tr, oracle_tr, atol=s.sizes.max() * 1e-5,
                err_msg=f"{s.network}/{s.workload}/{s.load}",
            )

    def test_results_match_oracle(self):
        scns = _scenarios()
        batch = simulate_flows_batch(scns)
        for s, r in zip(scns, batch.results):
            done, _, rem_mid, rem_end, _ = flows._oracle_steps(s)
            o = finalize(s, done, rem_mid, rem_end)
            assert o.admitted == r.admitted, (s.network, s.workload, s.load)
            assert np.isclose(o.finished_frac, r.finished_frac, atol=1e-6)
            assert np.isclose(o.backlog_frac, r.backlog_frac, atol=1e-4)
            for f in ("fct_p99_ms_small", "fct_p99_ms_mid",
                      "fct_p99_ms_large", "fct_mean_ms"):
                a, b = getattr(o, f), getattr(r, f)
                if np.isfinite(a) or np.isfinite(b):
                    assert np.isclose(a, b, rtol=1e-3, atol=1e-3), \
                        (s.network, s.workload, s.load, f, a, b)

    def test_simulate_equals_batch_of_one(self):
        """The public single-scenario API is the oracle; a batch of one
        must reproduce it."""
        scn = build_scenario("opera", "datamining", 0.2, seed=7, **TINY)
        via_oracle = flows.simulate("opera", "datamining", 0.2, seed=7, **TINY)
        r = simulate_flows_batch([scn]).results[0]
        assert via_oracle.admitted == r.admitted
        assert np.isclose(via_oracle.fct_mean_ms, r.fct_mean_ms,
                          rtol=1e-3, atol=1e-3)

    def test_mixed_scenario_parity(self):
        scn = build_mixed_scenario(
            0.05, bulk_load=0.5, num_hosts=16, horizon_s=0.1, seed=1
        )
        done, rem, rem_mid, rem_end, _ = flows._oracle_steps(scn)
        o = finalize(scn, done, rem_mid, rem_end)
        batch = simulate_flows_batch([scn])
        r = batch.results[0]
        assert np.isclose(o.finished_frac, r.finished_frac, atol=1e-6)
        np.testing.assert_allclose(
            batch.remaining_bytes[0], rem, atol=scn.sizes.max() * 1e-5
        )


class TestBatching:
    def test_batch_rows_independent(self):
        """vmap must not couple scenarios: a row's result is identical
        whether simulated alone or inside a mixed-size batch."""
        a = build_scenario("opera", "websearch", 0.08, seed=5, **TINY)
        b = build_scenario("expander", "datamining", 0.3, seed=6, **TINY)
        c = build_scenario("clos", "websearch", 0.2, seed=7, **TINY)
        alone = simulate_flows_batch([b]).results[0]
        batch = simulate_flows_batch([a, b, c]).results[1]
        assert alone.admitted == batch.admitted
        assert alone.finished_frac == batch.finished_frac
        for f in ("fct_p99_ms_small", "fct_p99_ms_mid", "fct_p99_ms_large",
                  "fct_mean_ms", "backlog_frac"):
            a_, b_ = getattr(alone, f), getattr(batch, f)
            assert np.isclose(a_, b_, rtol=1e-5, atol=1e-6) or (
                not np.isfinite(a_) and not np.isfinite(b_)
            ), (f, a_, b_)

    def test_grid_runs_full_cartesian_product(self):
        rows = simulate_grid(
            ("opera", "expander"), ("websearch",), (0.05, 0.2),
            seeds=(0, 1), **TINY
        )
        assert len(rows) == 8
        keys = {(r["network"], r["load"], r["seed"]) for r in rows}
        assert len(keys) == 8
        for r in rows:
            assert 0.0 <= r["finished_frac"] <= 1.0
            assert np.isfinite(r["backlog_frac"])

    def test_mismatched_step_counts_rejected(self):
        a = build_scenario("opera", "websearch", 0.1, **TINY)
        bad = dict(TINY, horizon_s=0.2)
        b = build_scenario("opera", "websearch", 0.1, **bad)
        with pytest.raises(ValueError, match="step count"):
            simulate_flows_batch([a, b])


class TestStabilityClassification:
    """The admission verdicts that set the paper's saturation loads."""

    KW = dict(num_hosts=64, horizon_s=0.5, dt_s=5e-4, tail_s=0.25)

    def test_websearch_knee_ordering(self):
        """Opera saturates near 10% on all-indirect Websearch; the
        expander keeps admitting well past that (paper: ~25%)."""
        rows = simulate_grid(
            ("opera", "expander"), ("websearch",), (0.05, 0.2),
            seeds=(0, 1), **self.KW
        )
        verdict = {
            (r["network"], r["load"]): r["admitted"] for r in rows
            if r["seed"] == 0
        }
        assert verdict[("opera", 0.05)]
        assert not verdict[("opera", 0.2)]
        assert verdict[("expander", 0.2)]

    def test_low_load_admitted_despite_heavy_tail(self):
        """A 100 MB+ flow arriving just before the snapshot is backlog
        no network could have served — it must not flip the verdict
        (the raw-backlog classifier used to fail this)."""
        rows = simulate_grid(
            ("opera",), ("datamining",), (0.02,), seeds=(0, 1, 2, 3),
            **self.KW
        )
        assert all(r["admitted"] for r in rows)

    def test_saturation_ladder_single_call(self):
        ladder = saturation_ladder(
            "opera", "websearch", (0.04, 0.08, 0.25), seeds=(0, 1),
            **self.KW
        )
        assert [r["load"] for r in ladder] == [0.04, 0.08, 0.25]
        assert ladder[0]["admitted_frac"] > 0.5
        assert ladder[-1]["admitted_frac"] < 0.5

    def test_saturation_load_bisection_and_ceiling(self):
        r = saturation_load(
            "opera", "websearch", ceiling=0.3, coarse_points=5,
            refine_points=3, **self.KW
        )
        assert not r.beyond_grid
        assert 0.04 <= r.load <= 0.2          # paper: ~10 %
        assert len(r.ladder) >= 5
        # a ceiling below the knee must be flagged, not silently clipped
        r2 = saturation_load(
            "opera", "websearch", ceiling=0.05, coarse_points=3,
            refine_points=0, **self.KW
        )
        assert r2.beyond_grid and r2.load == 0.05

    def test_saturation_load_numpy_fallback_agrees(self):
        kw = dict(self.KW, use_jax=False)
        a = saturation_load("opera", "websearch", ceiling=0.3,
                            coarse_points=5, refine_points=0, **kw)
        b = saturation_load("opera", "websearch", ceiling=0.3,
                            coarse_points=5, refine_points=0,
                            **dict(self.KW, use_jax=True))
        assert a.load == b.load
