"""Integration: training learns, checkpoint-restart is exact, serving runs."""
import jax

from repro import compat
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced_config
from repro.data.pipeline import SyntheticLM, device_batches
from repro.models import init_params
from repro.models.parallel import single_device_ctx
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import Request, ServeEngine
from repro.train.checkpoint import Checkpointer
from repro.train.opera_dp import init_opera_dp_state, make_opera_dp_train_step
from repro.train.trainer import init_train_state, make_train_step


def _mesh11():
    return compat.make_mesh((1, 1), ("data", "model"))


def _tiny():
    cfg = reduced_config(get_config("smollm-360m")).replace(
        num_layers=2, vocab_size=64
    )
    return cfg


class TestTrainerLearns:
    def test_loss_decreases_gspmd(self):
        cfg = _tiny()
        params = init_params(cfg, jax.random.key(0))
        opt = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=60)
        pctx = single_device_ctx()
        step = jax.jit(make_train_step(cfg, pctx, opt))
        state = init_train_state(cfg, params)
        src = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
        losses = []
        for i in range(60):
            state, m = step(state, jax.tree.map(jnp.asarray, src.batch_at(i)))
            losses.append(float(m["loss"]))
        first, last = np.mean(losses[:5]), np.mean(losses[-5:])
        assert last < first - 0.5, f"not learning: {first:.3f} -> {last:.3f}"
        assert last < np.log(cfg.vocab_size)  # beats uniform

    def test_opera_dp_equals_gspmd_on_one_device(self):
        """The explicit rotor DP trainer must produce the same update as
        the jit trainer when the mesh is 1x1 (all collectives degenerate)."""
        cfg = _tiny()
        params = init_params(cfg, jax.random.key(1))
        opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
        src = SyntheticLM(cfg.vocab_size, 16, 4, seed=1)
        batch = jax.tree.map(jnp.asarray, src.batch_at(0))

        mesh = _mesh11()
        from repro.launch.mesh import pctx_for_mesh

        pctx = pctx_for_mesh(mesh)
        with compat.set_mesh(mesh):
            s1 = init_train_state(cfg, params)
            s1, m1 = jax.jit(make_train_step(cfg, pctx, opt))(s1, batch)
            s2 = init_opera_dp_state(params)
            s2, m2 = jax.jit(make_opera_dp_train_step(cfg, pctx, opt))(s2, batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
        a = jax.tree.leaves(s1["params"])
        b = jax.tree.leaves(s2["params"])
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32),
                                       atol=1e-5, rtol=1e-5)

    def test_compressed_grad_sync_still_learns(self):
        cfg = _tiny()
        params = init_params(cfg, jax.random.key(2))
        opt = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=40)
        mesh = _mesh11()
        from repro.launch.mesh import pctx_for_mesh

        pctx = pctx_for_mesh(mesh)
        src = SyntheticLM(cfg.vocab_size, 32, 8, seed=2)
        with compat.set_mesh(mesh):
            step = jax.jit(
                make_opera_dp_train_step(cfg, pctx, opt, compress=True)
            )
            state = init_opera_dp_state(params, compress=True)
            losses = []
            for i in range(40):
                state, m = step(state, jax.tree.map(jnp.asarray, src.batch_at(i)))
                losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


class TestCheckpointRestart:
    def test_restart_is_bit_exact(self, tmp_path):
        """Kill-and-restore: steps 0..9 straight vs 0..4 + restore + 5..9."""
        cfg = _tiny()
        params = init_params(cfg, jax.random.key(3))
        opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
        pctx = single_device_ctx()
        step = jax.jit(make_train_step(cfg, pctx, opt))
        src = SyntheticLM(cfg.vocab_size, 16, 4, seed=3)

        sA = init_train_state(cfg, params)
        for i in range(10):
            sA, _ = step(sA, jax.tree.map(jnp.asarray, src.batch_at(i)))

        sB = init_train_state(cfg, params)
        ck = Checkpointer(str(tmp_path))
        for i in range(5):
            sB, _ = step(sB, jax.tree.map(jnp.asarray, src.batch_at(i)))
        ck.save(5, sB, blocking=True)
        sB2, start = ck.restore(sB)  # simulated crash + restart
        assert start == 5
        for i in range(start, 10):
            sB2, _ = step(sB2, jax.tree.map(jnp.asarray, src.batch_at(i)))

        for x, y in zip(jax.tree.leaves(sA["params"]),
                        jax.tree.leaves(sB2["params"])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestServeEngine:
    @pytest.mark.parametrize("arch", ["smollm-360m", "recurrentgemma-2b",
                                      "falcon-mamba-7b"])
    def test_continuous_batching(self, arch):
        cfg = reduced_config(get_config(arch))
        params = init_params(cfg, jax.random.key(0))
        eng = ServeEngine(cfg, params, single_device_ctx(), slots=2,
                          max_seq=32)
        rng = np.random.default_rng(0)
        for rid in range(4):  # more requests than slots
            eng.submit(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                max_new_tokens=4,
            ))
        done = eng.run_to_completion(max_ticks=64)
        assert len(done) == 4
        for r in done:
            assert len(r.out_tokens) >= 2
            assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)

    def test_greedy_decode_consistent_with_forward(self):
        """Engine's first decoded token == argmax of a fresh prefill."""
        from repro.models.model import forward_prefill

        cfg = reduced_config(get_config("smollm-360m"))
        params = init_params(cfg, jax.random.key(0))
        prompt = np.arange(1, 7, dtype=np.int32)
        eng = ServeEngine(cfg, params, single_device_ctx(), slots=1,
                          max_seq=32)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
        eng.step()
        logits, _ = forward_prefill(
            params, {"tokens": jnp.asarray(prompt[None])}, cfg,
            single_device_ctx(),
        )
        want = int(jnp.argmax(logits[0]))
        got = eng.finished[0].out_tokens[0] if eng.finished else \
            [r for r in eng.active if r][0].out_tokens[0]
        assert got == want
