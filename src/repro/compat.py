"""Version-compat shims for the pinned accelerator stack.

The codebase is written against the modern jax surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.make_mesh(..., axis_types=...)``) while the seed
container pins jax 0.4.37, where the same machinery lives under older
names with an older keyword surface.  Policy: **all** repro code (src,
tests, benchmarks, examples) imports these three symbols from
``repro.compat`` instead of touching ``jax.*`` directly, so a future jax
bump is a one-file change.

shard_map(f, mesh=None, *, in_specs, out_specs, check_vma=..., axis_names=...)
    Modern call surface on every jax.  On 0.4.x it lowers onto
    ``jax.experimental.shard_map.shard_map`` with

      * ``check_vma``   -> ``check_rep``
      * ``axis_names``  -> ``auto = mesh.axis_names - axis_names``
        (partial-manual binding: unnamed axes stay GSPMD-auto inside)
      * ``mesh=None``   -> the ambient mesh installed by ``set_mesh``
        (0.4.x shard_map requires a concrete mesh argument).

make_mesh(shape, axes)
    ``jax.make_mesh`` with explicitly Auto axis types where the kwarg
    exists; plain ``jax.make_mesh`` on 0.4.x (every axis is Auto there).

set_mesh(mesh)
    Context manager installing `mesh` as the ambient mesh.  Native
    ``jax.set_mesh`` when present; the classic ``with mesh:`` thread
    resource otherwise (which is exactly what 0.4.x shard_map/jit read).
"""
from __future__ import annotations

import jax

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")

# Partial-manual binding (axis_names a strict subset of the mesh, the rest
# staying GSPMD-auto inside) exists on 0.4.x as the experimental ``auto=``
# kwarg but hard-aborts in XLA's sharding propagation on CPU
# (hlo_sharding_util: `sharding.IsManualSubgroup()` check).  Callers that
# need a partial-manual region must consult this flag and fall back to a
# pure-GSPMD formulation when it is False.
HAS_PARTIAL_MANUAL = HAS_NATIVE_SHARD_MAP


if HAS_NATIVE_SHARD_MAP:

    def shard_map(f, mesh=None, *, in_specs, out_specs, check_vma=True,
                  axis_names=None):
        kw = dict(in_specs=in_specs, out_specs=out_specs, check_vma=check_vma)
        if mesh is not None:
            kw["mesh"] = mesh
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, **kw)

else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def _ambient_mesh():
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m.empty:
            raise ValueError(
                "shard_map(mesh=None) needs an ambient mesh: wrap the call "
                "in repro.compat.set_mesh(mesh) on jax 0.4.x"
            )
        return m

    def shard_map(f, mesh=None, *, in_specs, out_specs, check_vma=True,
                  axis_names=None):
        m = mesh if mesh is not None else _ambient_mesh()
        auto = frozenset()
        if axis_names is not None:
            auto = frozenset(m.axis_names) - frozenset(axis_names)
        # legacy partial-manual (auto nonempty) cannot check replication
        return _legacy_shard_map(
            f, mesh=m, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma and not auto, auto=auto,
        )


def axis_size(axis_name) -> int:
    """Static size of a bound mesh axis (``lax.axis_size`` on modern jax;
    the trace-time-constant ``psum(1, axis)`` fold on 0.4.x)."""
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def make_mesh(shape, axes, devices=None):
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kw)


def set_mesh(mesh):
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    # 0.4.x: Mesh is itself the ambient-mesh context manager
    return mesh


__all__ = ["HAS_NATIVE_SHARD_MAP", "HAS_PARTIAL_MANUAL", "axis_size",
           "shard_map", "make_mesh", "set_mesh"]
