"""Layer 1 — Opera artifact verifier (no simulation).

Verifies, directly from the design-time artifacts, the four structural
invariants the paper's correctness argument rests on (PAPER.md §3;
the spectral framing follows Harsh et al., *Expander Datacenters*):

SC-INV-MATCH   each slice of ``matching_tensor()`` is the union of the
               slice's live matchings: every live matching is an
               involutive permutation, no two live matchings share an
               edge, and the exported adjacency is the exact symmetric
               0/1 union with no self-maps (empty diagonal).
SC-INV-COVER   the union over one full cycle covers every ordered
               off-diagonal rack pair exactly ``u/groups - 1`` times
               (each matching is installed for u/groups slices, one of
               them dark) — the single-hop all-to-all bulk guarantee.
SC-INV-EXPAND  every slice graph is connected, and — when its minimum
               live degree is >= 3 — its degree-normalized spectral gap
               is at least ``gap_frac * ramanujan_bound(min_degree)``.
               Degree-<3 slices are structurally cycles/matchings
               (bipartite, gap 0) and are held to connectivity only.
SC-INV-RECONF  consecutive slices (cyclically) differ in at most
               ``2 * groups * N`` directed links — only the
               reconfiguring switch groups' matchings may change, the
               rest of the fabric stays up (piecewise reconfiguration).
SC-INV-FABRIC  the static comparison fabrics (`expander_union`,
               `random_regular_expander`) are symmetric, self-map-free,
               connected, and meet the same spectral bound.
SC-INV-FAULT   fault-masked capacity tensors (`netsim.faults.
               masked_tensor`) stay symmetric and never add capacity
               beyond the live fabric, a seeded link draw really removes
               realized uplinks, and every slice stays connected under
               every combination of up to ``switch_fault_tolerance``
               failed circuit switches — the Fig. 11c budget the
               realization was selected for.

All checks return ``Finding`` lists; ``verify_topology`` bundles the
four topology rules.  Tests inject corrupted tensors via the
``tensor=`` override to prove each rule actually fires.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.expander import ramanujan_bound, spectral_gap
from repro.core.topology import OperaTopology, _connected
from repro.staticcheck.findings import Finding


@dataclasses.dataclass(frozen=True)
class InvariantConfig:
    """Bounds for the expander check (documented in ROADMAP.md).

    `gap_frac` is the required fraction of the Ramanujan-optimal gap at
    the slice's *minimum* live degree; 0.3 is comfortably below what
    random matching unions achieve at the Appendix-B design points
    (measured: 0.77x at k12-n108-g1, 0.50x at k12-n108-g2) while still
    rejecting near-bipartite and poorly-mixed slices.
    """

    gap_frac: float = 0.3
    min_degree_for_gap: int = 3
    max_slices: Optional[int] = None   # cap slices checked (None = all)


def _slices(topo: OperaTopology, cfg: InvariantConfig) -> range:
    n = topo.num_slices
    if cfg.max_slices is not None:
        n = min(n, cfg.max_slices)
    return range(n)


def _tensor(topo: OperaTopology, tensor: Optional[np.ndarray]) -> np.ndarray:
    return topo.matching_tensor() if tensor is None else np.asarray(tensor)


def check_matching_union(
    topo: OperaTopology,
    tensor: Optional[np.ndarray] = None,
    config: InvariantConfig = InvariantConfig(),
) -> List[Finding]:
    """SC-INV-MATCH: slices are disjoint unions of involutive matchings."""
    out: List[Finding] = []
    ten = _tensor(topo, tensor)
    n = topo.num_racks
    ident = np.arange(n)

    def bad(msg: str) -> None:
        out.append(Finding("SC-INV-MATCH", msg, path=f"slice-tensor[{topo.num_racks}r]"))

    if ten.shape != (topo.num_slices, n, n):
        bad(f"tensor shape {ten.shape} != {(topo.num_slices, n, n)}")
        return out
    for t in _slices(topo, config):
        union = np.zeros((n, n), dtype=np.int64)
        for s, p in topo.live_matchings(t):
            if not np.array_equal(p[p], ident):
                bad(f"slice {t}: switch {s} matching is not an involution")
                continue
            mask = p != ident
            union[ident[mask], p[mask]] += 1
        if (union > 1).any():
            bad(f"slice {t}: live matchings overlap (shared edge)")
        sl = ten[t]
        if not np.isin(sl, (0.0, 1.0)).all():
            bad(f"slice {t}: adjacency entries outside {{0, 1}}")
        if np.diagonal(sl).any():
            bad(f"slice {t}: self-map (non-empty diagonal)")
        if not np.array_equal(sl, sl.T):
            bad(f"slice {t}: adjacency not symmetric")
        if not np.array_equal(sl != 0, union >= 1):
            bad(f"slice {t}: adjacency != union of live matchings")
    return out


def check_cycle_coverage(
    topo: OperaTopology,
    tensor: Optional[np.ndarray] = None,
    config: InvariantConfig = InvariantConfig(),
) -> List[Finding]:
    """SC-INV-COVER: exact single-hop all-to-all coverage per cycle."""
    out: List[Finding] = []
    ten = _tensor(topo, tensor)
    n = topo.num_racks
    rounds = topo.num_switches // topo.groups
    expected = rounds - 1
    if expected <= 0:
        return [Finding("SC-INV-COVER",
                        f"degenerate schedule: u={topo.num_switches} groups="
                        f"{topo.groups} leaves no live slices per matching",
                        path="schedule")]
    cover = (ten != 0).sum(axis=0)
    off = ~np.eye(n, dtype=bool)
    never = int((cover[off] == 0).sum())
    if never:
        out.append(Finding(
            "SC-INV-COVER",
            f"{never} ordered rack pairs get no direct circuit in a cycle",
            path="cycle-union"))
    wrong = int((cover[off] != expected).sum())
    if wrong:
        out.append(Finding(
            "SC-INV-COVER",
            f"{wrong} ordered rack pairs covered != {expected} times per "
            f"cycle (u/groups - 1)",
            path="cycle-union"))
    if np.diagonal(cover).any():
        out.append(Finding("SC-INV-COVER", "diagonal covered (self-circuit)",
                           path="cycle-union"))
    return out


def check_expander(
    topo: OperaTopology,
    tensor: Optional[np.ndarray] = None,
    config: InvariantConfig = InvariantConfig(),
) -> List[Finding]:
    """SC-INV-EXPAND: every slice connected; gap bound when degree >= 3."""
    out: List[Finding] = []
    ten = _tensor(topo, tensor)
    for t in _slices(topo, config):
        adj = ten[t] != 0
        if not _connected(adj):
            out.append(Finding("SC-INV-EXPAND",
                               f"slice {t} graph is disconnected",
                               path=f"slice[{t}]"))
            continue
        dmin = int(adj.sum(axis=1).min())
        if dmin >= config.min_degree_for_gap:
            need = config.gap_frac * ramanujan_bound(dmin)
            gap = spectral_gap(adj)
            if gap < need:
                out.append(Finding(
                    "SC-INV-EXPAND",
                    f"slice {t} spectral gap {gap:.4f} < required "
                    f"{need:.4f} ({config.gap_frac} x ramanujan({dmin}))",
                    path=f"slice[{t}]"))
    return out


def check_reconfiguration(
    topo: OperaTopology,
    tensor: Optional[np.ndarray] = None,
    config: InvariantConfig = InvariantConfig(),
) -> List[Finding]:
    """SC-INV-RECONF: at most 2*groups matchings' links change per boundary."""
    out: List[Finding] = []
    ten = _tensor(topo, tensor)
    n = topo.num_racks
    bound = 2 * topo.groups * n     # directed links: groups leave + groups join
    T = ten.shape[0]
    for t in range(T):
        a = ten[t] != 0
        b = ten[(t + 1) % T] != 0
        changed = int((a ^ b).sum())
        if changed > bound:
            out.append(Finding(
                "SC-INV-RECONF",
                f"slice {t}->{(t + 1) % T}: {changed} directed links changed"
                f" > bound {bound} (2 x groups x N); reconfiguration is not"
                " piecewise",
                path=f"slice[{t}]"))
    return out


def verify_topology(
    topo: OperaTopology,
    tensor: Optional[np.ndarray] = None,
    config: InvariantConfig = InvariantConfig(),
) -> List[Finding]:
    """All four topology invariants on one tensor export."""
    ten = _tensor(topo, tensor)
    out: List[Finding] = []
    out += check_matching_union(topo, ten, config)
    out += check_cycle_coverage(topo, ten, config)
    out += check_expander(topo, ten, config)
    out += check_reconfiguration(topo, ten, config)
    return out


def check_fault_masks(
    topo: OperaTopology,
    budget: int = 0,
    seed: int = 0,
    link_frac: float = 0.04,
    config: InvariantConfig = InvariantConfig(),
    tensor: Optional[np.ndarray] = None,
) -> List[Finding]:
    """SC-INV-FAULT: fault-masked tensors are well-formed; the realization
    survives its declared switch-fault budget.

    Verifies two artifacts of `netsim.faults.masked_tensor`:

    * a seeded link-failure draw (`FailureSchedule.draw`) must yield
      per-slice tensors that are symmetric, a *subset* of the live fabric
      (masking only ever removes capacity), and strictly smaller than it
      (the sampler hit realized uplinks, not non-edges);
    * every combination of up to ``budget`` failed circuit switches must
      leave every checked slice connected — the `switch_fault_tolerance`
      property the design-time generate-and-test loop (§3.3) selected
      the realization for, re-verified here on the exported artifact.
    """
    import itertools

    from repro.netsim.faults import (
        FailureEvent,
        FailureSchedule,
        masked_tensor,
    )

    out: List[Finding] = []
    base = _tensor(topo, tensor)

    def bad(msg: str, path: str) -> None:
        out.append(Finding("SC-INV-FAULT", msg, path=path))

    draw = FailureSchedule.draw(topo, seed=seed, link_frac=link_frac,
                                onset_step=0, detect_lag=0)
    masked = masked_tensor(topo, draw, tensor=base)
    removed = 0
    for t in _slices(topo, config):
        sl = masked[t]
        if not np.array_equal(sl, sl.T):
            bad(f"slice {t}: fault-masked tensor not symmetric",
                f"masked[{t}]")
        extra = (sl != 0) & (base[t] == 0)
        if extra.any():
            bad(f"slice {t}: mask added {int(extra.sum())} edges outside "
                "the live fabric", f"masked[{t}]")
        removed += int(((base[t] != 0) & (sl == 0)).sum())
    if removed == 0:
        bad(f"link draw (seed={seed}, frac={link_frac}) removed no "
            "capacity — the sampler missed the realized uplinks",
            "link-draw")

    for k in range(1, budget + 1):
        for combo in itertools.combinations(range(topo.num_switches), k):
            sched = FailureSchedule(
                num_racks=topo.num_racks,
                num_switches=topo.num_switches,
                events=(FailureEvent("switch", combo, onset_step=0,
                                     detect_lag=0),))
            m = masked_tensor(topo, sched, tensor=base)
            for t in _slices(topo, config):
                if not _connected(m[t] != 0):
                    bad(f"slice {t} disconnects under switch failures "
                        f"{combo} — inside the declared fault budget "
                        f"{budget}", f"switches{combo}")
                    break   # one finding per combo is enough
    return out


def check_static_fabric(
    adj: np.ndarray,
    name: str,
    config: InvariantConfig = InvariantConfig(),
) -> List[Finding]:
    """SC-INV-FABRIC: a static comparison fabric is a healthy expander."""
    out: List[Finding] = []
    adj = np.asarray(adj) != 0
    if np.diagonal(adj).any():
        out.append(Finding("SC-INV-FABRIC", f"{name}: self-loops", path=name))
    if not np.array_equal(adj, adj.T):
        out.append(Finding("SC-INV-FABRIC", f"{name}: not symmetric", path=name))
    if not _connected(adj):
        out.append(Finding("SC-INV-FABRIC", f"{name}: disconnected", path=name))
        return out
    dmin = int(adj.sum(axis=1).min())
    if dmin >= config.min_degree_for_gap:
        need = config.gap_frac * ramanujan_bound(dmin)
        gap = spectral_gap(adj)
        if gap < need:
            out.append(Finding(
                "SC-INV-FABRIC",
                f"{name}: spectral gap {gap:.4f} < required {need:.4f}",
                path=name))
    return out
