"""Static-analysis suite for the Opera reproduction.

Two layers, one finding vocabulary (`Finding`, rule IDs `SC-*`):

* **Artifact verifier** (`staticcheck.invariants`) — proves the structural
  invariants Opera's correctness argument rests on (PAPER.md §3) directly
  from design-time artifacts, without simulating: every slice of
  `OperaTopology.matching_tensor()` is a disjoint union of involutive
  matchings with no self-maps, one cycle gives exact single-hop coverage
  of every ordered rack pair, every slice graph is a connected expander,
  and consecutive slices differ by at most the reconfiguring groups'
  matchings.
* **Code analyzer** (`staticcheck.jaxpr_rules`, `staticcheck.ast_rules`)
  — traces the jitted engine entry points to closed jaxprs and flags
  float64 leaks / host callbacks / sweep-grid recompilation, and walks
  the tree's ASTs to enforce the repo policies from ROADMAP Architecture
  notes (the `repro.compat` import rule, oracle<->JAX lockstep pairs,
  kernel trio completeness, annotated host-side float64 staging).

Run it: ``python -m repro.staticcheck`` (CLI, exits non-zero on
violations, writes ``results/staticcheck.json``) or via
``tests/test_staticcheck.py`` in tier-1.  Per-line allowlisting uses a
directive comment: ``# staticcheck: ok SC-AST-F64 (reason)`` on the
flagged line or the line above it.
"""
from repro.staticcheck.findings import Finding, Report

__all__ = ["Finding", "Report"]
