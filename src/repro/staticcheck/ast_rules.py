"""Layer 2b — AST rules enforcing repo code policies (ROADMAP notes).

SC-AST-COMPAT    all code must import shard_map/set_mesh/make_mesh from
                 ``repro.compat`` — direct ``jax.shard_map`` /
                 ``jax.set_mesh`` / ``jax.make_mesh`` attribute access or
                 ``jax.experimental.shard_map`` imports are banned
                 outside ``repro/compat.py``.
SC-AST-SHADOW    no module other than ``repro/compat.py`` may (re)define
                 a top-level ``shard_map``/``set_mesh``/``make_mesh`` —
                 a shadowing re-export splits the canonical surface.
SC-AST-F64       float32 device-engine modules (``netsim/*_jax.py``) may
                 touch float64 only on explicitly annotated host-side
                 staging lines (``# staticcheck: ok SC-AST-F64 (...)``).
SC-AST-TRIO      every kernel package under ``kernels/`` ships the full
                 ``kernel.py`` / ``ops.py`` / ``ref.py`` trio.
SC-AST-LOCKSTEP  oracle<->JAX engine pairs must change together in a
                 diff (``git diff --name-only``): fluid.py<->fluid_jax.py,
                 flows.py<->flows_jax.py.  A diff touching
                 ``netsim/faults.py`` carries failure *semantics* (the
                 per-step mask/window math both members of each pair
                 mirror), so it must touch both members of each pair
                 too — or neither gets a pass: an untouched pair under a
                 faults.py diff is flagged for review.
"""
from __future__ import annotations

import ast
import fnmatch
import os
import subprocess
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.staticcheck.findings import Finding, WARNING, allowed_lines

COMPAT_SURFACE = ("shard_map", "set_mesh", "make_mesh")
COMPAT_MODULE = os.path.join("repro", "compat.py")
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "scripts")
ENGINE_F64_GLOBS = ("*/netsim/*_jax.py",)
LOCKSTEP_PAIRS: Tuple[Tuple[str, str], ...] = (
    ("src/repro/netsim/fluid.py", "src/repro/netsim/fluid_jax.py"),
    ("src/repro/netsim/flows.py", "src/repro/netsim/flows_jax.py"),
)
# failure-semantics module: its per-step mask/window math is mirrored
# inside every member of LOCKSTEP_PAIRS (faults.step_masks <->
# fluid_jax._slice_step_faulted, apply_flow_faults windows <-> both
# flow engines), so a diff touching it couples to every pair
FAULTS_MODULE = "src/repro/netsim/faults.py"


def iter_py_files(root: str, dirs: Sequence[str] = SCAN_DIRS) -> Iterable[str]:
    for d in dirs:
        base = os.path.join(root, d)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [x for x in dirnames if x != "__pycache__"]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def _rel(root: str, path: str) -> str:
    return os.path.relpath(path, root)


def _is_compat(rel: str) -> bool:
    return rel.replace(os.sep, "/").endswith("repro/compat.py")


def check_compat_policy(root: str, path: str, tree: ast.AST,
                        source: str) -> List[Finding]:
    """SC-AST-COMPAT + SC-AST-SHADOW on one parsed module."""
    rel = _rel(root, path)
    if _is_compat(rel):
        return []
    out: List[Finding] = []

    def flag(rule: str, node: ast.AST, msg: str) -> None:
        out.append(Finding(rule, msg, path=rel, line=node.lineno))

    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            mod = node.module
            if mod.startswith("jax.experimental.shard_map"):
                flag("SC-AST-COMPAT", node,
                     "import jax.experimental.shard_map directly — use "
                     "repro.compat.shard_map")
            elif mod == "jax.experimental" and any(
                a.name == "shard_map" for a in node.names
            ):
                flag("SC-AST-COMPAT", node,
                     "from jax.experimental import shard_map — use "
                     "repro.compat.shard_map")
            elif mod == "jax" and any(
                a.name in COMPAT_SURFACE for a in node.names
            ):
                flag("SC-AST-COMPAT", node,
                     "import the mesh surface from repro.compat, not jax")
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name.startswith("jax.experimental.shard_map"):
                    flag("SC-AST-COMPAT", node,
                         "import jax.experimental.shard_map directly — use "
                         "repro.compat.shard_map")
        elif isinstance(node, ast.Attribute):
            if (isinstance(node.value, ast.Name) and node.value.id == "jax"
                    and node.attr in COMPAT_SURFACE):
                flag("SC-AST-COMPAT", node,
                     f"jax.{node.attr} used directly — use "
                     f"repro.compat.{node.attr}")
            elif (isinstance(node.value, ast.Attribute)
                  and node.value.attr == "experimental"
                  and isinstance(node.value.value, ast.Name)
                  and node.value.value.id == "jax"
                  and node.attr == "shard_map"):
                flag("SC-AST-COMPAT", node,
                     "jax.experimental.shard_map used directly — use "
                     "repro.compat.shard_map")

    body = getattr(tree, "body", [])
    for node in body:
        names: List[str] = []
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names = [node.name]
        elif isinstance(node, ast.Assign):
            names = [t.id for t in node.targets if isinstance(t, ast.Name)]
        for name in names:
            if name in COMPAT_SURFACE:
                out.append(Finding(
                    "SC-AST-SHADOW",
                    f"top-level `{name}` shadows the canonical "
                    f"repro.compat.{name} surface",
                    path=rel, line=node.lineno))
    return out


def check_engine_f64(root: str, path: str, tree: ast.AST,
                     source: str) -> List[Finding]:
    """SC-AST-F64 on one parsed module (engine modules only)."""
    rel = _rel(root, path).replace(os.sep, "/")
    if not any(fnmatch.fnmatch(rel, g) for g in ENGINE_F64_GLOBS):
        return []
    ok = allowed_lines(source, "SC-AST-F64")
    out: List[Finding] = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Attribute) and node.attr == "float64"
                and node.lineno not in ok):
            out.append(Finding(
                "SC-AST-F64",
                "float64 in a float32 device engine — move it to annotated "
                "host-side staging (`# staticcheck: ok SC-AST-F64 (...)`) "
                "or drop it",
                path=_rel(root, path), line=node.lineno))
    return out


def check_kernel_trios(root: str) -> List[Finding]:
    """SC-AST-TRIO over src/repro/kernels/*."""
    out: List[Finding] = []
    base = os.path.join(root, "src", "repro", "kernels")
    if not os.path.isdir(base):
        return out
    for name in sorted(os.listdir(base)):
        pkg = os.path.join(base, name)
        if not os.path.isdir(pkg) or name == "__pycache__":
            continue
        missing = [f for f in ("kernel.py", "ops.py", "ref.py")
                   if not os.path.exists(os.path.join(pkg, f))]
        if missing:
            out.append(Finding(
                "SC-AST-TRIO",
                f"kernel package `{name}` missing {', '.join(missing)} "
                "(kernel/ops/ref trio is mandatory)",
                path=_rel(root, pkg)))
    return out


def git_changed_files(root: str, base: Optional[str] = None) -> List[str]:
    """Changed files vs `base` (or the working tree vs HEAD)."""
    cmd = ["git", "diff", "--name-only"] + ([base] if base else ["HEAD"])
    try:
        res = subprocess.run(cmd, cwd=root, capture_output=True, text=True,
                             check=True)
    except (OSError, subprocess.CalledProcessError):
        return []
    return [ln.strip() for ln in res.stdout.splitlines() if ln.strip()]


def check_lockstep(changed_files: Sequence[str]) -> List[Finding]:
    """SC-AST-LOCKSTEP over a diff file list."""
    changed = {f.replace(os.sep, "/") for f in changed_files}
    out: List[Finding] = []
    faulted = FAULTS_MODULE in changed
    for a, b in LOCKSTEP_PAIRS:
        in_a, in_b = a in changed, b in changed
        if in_a != in_b:
            lone, partner = (a, b) if in_a else (b, a)
            out.append(Finding(
                "SC-AST-LOCKSTEP",
                f"{lone} changed without its lockstep partner {partner} — "
                "oracle and JAX engine share per-step math; change them "
                "together (ROADMAP Architecture notes)",
                path=lone, severity=WARNING))
        elif faulted and not in_a:
            out.append(Finding(
                "SC-AST-LOCKSTEP",
                f"{FAULTS_MODULE} changed but neither {a} nor {b} did — "
                "failure semantics (per-step masks / fault windows) are "
                "mirrored inside both engines; touch both pair members "
                "or confirm the diff is schedule-plumbing only",
                path=FAULTS_MODULE, severity=WARNING))
    return out


def scan_tree(root: str, diff_base: Optional[str] = None,
              lockstep: bool = True) -> List[Finding]:
    """All AST rules over the repo tree."""
    out: List[Finding] = []
    for path in iter_py_files(root):
        with open(path, "r") as fh:
            source = fh.read()
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as e:
            out.append(Finding("SC-AST-PARSE", f"syntax error: {e}",
                               path=_rel(root, path), line=e.lineno))
            continue
        out += check_compat_policy(root, path, tree, source)
        out += check_engine_f64(root, path, tree, source)
    out += check_kernel_trios(root)
    if lockstep:
        out += check_lockstep(git_changed_files(root, diff_base))
    return out
