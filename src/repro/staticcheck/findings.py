"""Finding/report types shared by both static-analysis layers."""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Sequence

# Severities: an ``error`` fails the CLI / CI gate; a ``warning`` is
# reported but does not flip the exit code.
ERROR = "error"
WARNING = "warning"

_DIRECTIVE = re.compile(r"#\s*staticcheck:\s*ok\s+(?P<rules>[A-Z0-9,\- ]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str                      # e.g. "SC-INV-COVER"
    message: str
    path: Optional[str] = None     # file (code rules) / artifact name (invariants)
    line: Optional[int] = None
    severity: str = ERROR

    @property
    def location(self) -> str:
        if self.path is None:
            return "<artifact>"
        return f"{self.path}:{self.line}" if self.line else self.path

    def __str__(self) -> str:
        return f"{self.severity}: {self.rule} {self.location}: {self.message}"


@dataclasses.dataclass
class Report:
    findings: List[Finding] = dataclasses.field(default_factory=list)
    checks_run: List[str] = dataclasses.field(default_factory=list)

    def extend(self, findings: Sequence[Finding], check: str) -> None:
        self.checks_run.append(check)
        self.findings.extend(findings)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == ERROR]

    @property
    def ok(self) -> bool:
        return not self.errors

    def by_rule(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out

    def to_dict(self) -> Dict:
        return {
            "ok": self.ok,
            "checks_run": self.checks_run,
            "num_findings": len(self.findings),
            "num_errors": len(self.errors),
            "by_rule": self.by_rule(),
            "findings": [dataclasses.asdict(f) for f in self.findings],
        }

    def to_json(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_dict(), fh, indent=2, sort_keys=True)
            fh.write("\n")


def allowed_lines(source: str, rule: str) -> set:
    """Line numbers (1-based) where `rule` is allowlisted by a
    ``# staticcheck: ok RULE (...)`` directive on that line or the line
    directly above it."""
    ok: set = set()
    for i, text in enumerate(source.splitlines(), start=1):
        m = _DIRECTIVE.search(text)
        if m and rule in m.group("rules"):
            ok.add(i)
            ok.add(i + 1)
    return ok
