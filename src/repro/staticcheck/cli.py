"""``python -m repro.staticcheck`` — run the full static-analysis suite.

Stages (select with ``--layers``):

* ``invariants`` — build the default Appendix-B design points and verify
  the four topology invariants, the static comparison fabrics, and the
  fault-mask artifact (SC-INV-FAULT, incl. each design's declared
  switch-fault budget).
* ``ast``        — walk every .py under src/tests/benchmarks/examples/
  scripts for the compat/lockstep/trio/f64 policies.
* ``jaxpr``      — trace the thirteen engine entry points (dense +
  sparse + tiled-flow netsim engines plus their faulted lowerings, five
  Pallas kernels) and run the f64/callback/recompile rules.

Exit code 0 iff no ``error``-severity findings.  ``--json`` writes the
machine-readable report (CI keeps ``results/staticcheck.json``).
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Tuple

from repro.staticcheck.findings import Report

# Default Appendix-B design points: (k, num_racks, groups).  k12-n108-g1
# is the paper's 648-host §4 point; k12-n108-g2 exercises grouped
# reconfiguration; k8-n16-g1 is the small end of the App-B grid.
DEFAULT_DESIGNS: Tuple[Tuple[int, int, int], ...] = (
    (12, 108, 1),
    (12, 108, 2),
    (8, 16, 1),
)

# Declared per-slice switch-fault budgets per (k, num_racks, groups):
# SC-INV-FAULT proves every slice stays *fully* connected under every
# combination of up to this many failed circuit switches.  The paper's
# headline 2-of-6 tolerance (Fig. 11c) is a *cycle-level* property —
# a slice that loses 2 of its 5 live matchings can transiently fragment,
# while every pair still reaches every other in the surrounding slices
# and throughput retention stays >= 90% — and is verified dynamically by
# benchmarks/fig11_faults.py; the strict every-slice guarantee any
# k12-n108 realization attains is 1.  Designs not listed get budget 0 —
# SC-INV-FAULT still verifies their masked-tensor well-formedness, just
# no switch-combination sweep.
SWITCH_FAULT_BUDGETS = {(12, 108, 1): 1}


def _parse_designs(text: str) -> List[Tuple[int, int, int]]:
    out = []
    for part in text.split(","):
        k, n, g = (int(x.lstrip("kng")) for x in part.strip().split("-"))
        out.append((k, n, g))
    return out


def run_invariants(report: Report, designs, gap_frac: float) -> None:
    from repro.core.expander import random_regular_expander
    from repro.core.topology import build_opera_topology, expander_union
    from repro.staticcheck.invariants import (
        InvariantConfig,
        check_fault_masks,
        check_static_fabric,
        verify_topology,
    )

    def tag(found, k, n, g):
        for f in found:
            report.findings.append(type(f)(
                f.rule, f"[k{k}-n{n}-g{g}] {f.message}",
                path=f.path, line=f.line, severity=f.severity))

    cfg = InvariantConfig(gap_frac=gap_frac)
    for k, n, g in designs:
        topo = build_opera_topology(n, k // 2, seed=0, groups=g)
        tag(verify_topology(topo, config=cfg), k, n, g)
        report.checks_run.append(f"invariants:k{k}-n{n}-g{g}")
        budget = SWITCH_FAULT_BUDGETS.get((k, n, g), 0)
        tag(check_fault_masks(topo, budget=budget, config=cfg), k, n, g)
        report.checks_run.append(f"invariants:fault:k{k}-n{n}-g{g}")
    # static comparison fabrics (fig 2/4/7 baselines)
    report.extend(
        check_static_fabric(expander_union(130, 7, seed=0),
                            "expander_union(130, 7)", cfg),
        "invariants:expander_union",
    )
    report.extend(
        check_static_fabric(random_regular_expander(130, 7, seed=0),
                            "random_regular_expander(130, 7)", cfg),
        "invariants:random_regular_expander",
    )


def run_ast(report: Report, root: str, diff_base) -> None:
    from repro.staticcheck.ast_rules import scan_tree

    report.extend(scan_tree(root, diff_base=diff_base), "ast:tree")


def run_jaxpr(report: Report) -> None:
    from repro.staticcheck.jaxpr_rules import (
        check_callbacks,
        check_float64,
        count_fault_lowerings,
        count_sparse_lowerings,
        count_sweep_lowerings,
        count_tiled_lowerings,
        trace_entrypoints,
    )

    entries, trace_findings = trace_entrypoints()
    report.extend(trace_findings, "jaxpr:trace")
    report.extend(check_float64(entries), "jaxpr:float64")
    report.extend(check_callbacks(entries), "jaxpr:callbacks")
    _, _, recompile = count_sweep_lowerings()
    report.extend(recompile, "jaxpr:recompile")
    _, fault_recompile = count_fault_lowerings()
    report.extend(fault_recompile, "jaxpr:fault-recompile")
    _, sparse_recompile = count_sparse_lowerings()
    report.extend(sparse_recompile, "jaxpr:sparse-recompile")
    _, tiled_recompile = count_tiled_lowerings()
    report.extend(tiled_recompile, "jaxpr:tiled-recompile")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.staticcheck",
        description="Opera invariant verifier + jaxpr/AST static analysis",
    )
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto from this file)")
    ap.add_argument("--layers", default="invariants,ast,jaxpr",
                    help="comma list of invariants,ast,jaxpr")
    ap.add_argument("--designs", default=None,
                    help="design points as k12-n108-g1,... "
                         "(default Appendix-B set)")
    ap.add_argument("--gap-frac", type=float, default=0.3,
                    help="required fraction of the Ramanujan-optimal "
                         "spectral gap (default 0.3)")
    ap.add_argument("--diff-base", default=None,
                    help="git rev to diff against for the lockstep rule "
                         "(default: working tree vs HEAD)")
    ap.add_argument("--json", default=None,
                    help="write machine-readable report to this path")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    root = args.root or os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    layers = [x.strip() for x in args.layers.split(",") if x.strip()]
    designs = (_parse_designs(args.designs) if args.designs
               else list(DEFAULT_DESIGNS))

    report = Report()
    if "invariants" in layers:
        run_invariants(report, designs, args.gap_frac)
    if "ast" in layers:
        run_ast(report, root, args.diff_base)
    if "jaxpr" in layers:
        run_jaxpr(report)

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        report.to_json(args.json)
    if not args.quiet:
        for f in report.findings:
            print(f)
        print(
            f"staticcheck: {len(report.checks_run)} checks, "
            f"{len(report.findings)} findings "
            f"({len(report.errors)} errors) -> "
            f"{'FAIL' if not report.ok else 'OK'}"
        )
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
