"""Layer 2a — jaxpr rules over the jitted engine entry points.

Each engine's device program is traced (abstractly — nothing executes)
to a closed jaxpr under ``jax_enable_x64`` so dtype leaks that silent
x64-off demotion would mask become visible, then walked recursively
(scan/cond/pjit/pallas_call sub-jaxprs included):

SC-JAX-F64        a float64 value materializes inside a float32 engine —
                  a weak-type or literal promotion that doubles memory
                  traffic and silently de-synchronizes the f32 oracle
                  lockstep.
SC-JAX-CALLBACK   a host callback primitive (pure_callback/io_callback/
                  debug_callback/outside_call) inside a hot loop —
                  forces a device->host sync every step.
SC-JAX-RECOMPILE  the sweep grid compiles more than once per design
                  point: `netsim/sweep.py` must reuse one lowering of
                  `fluid_jax._run_batch` per (k, num_racks, groups)
                  shape, never one per load/seed scenario.  The fault
                  path has the same contract (`count_fault_lowerings`):
                  failure timelines are int32 *data* operands of
                  `_run_batch_faulted`, so distinct failure draws must
                  never trigger fresh lowerings.

Traced entry points: ``fluid_jax._run_batch`` / ``_run_batch_faulted``
(the dense device programs under ``simulate_rotor_bulk_batch``),
``fluid_jax._sparse_slice_step`` / ``_sparse_slice_step_faulted`` (the
sparse engine's per-step programs — ``count_sparse_lowerings`` holds
them to one lowering per design point across slices and cycles),
``flows_jax._run_batch`` / ``_run_batch_faulted`` (under
``simulate_grid`` / ``simulate_flows_batch``),
``flows_jax._run_tiled_chunk`` / ``_run_tiled_chunk_faulted`` (the
streaming tiled flow engine's chunk programs — shapes depend on the
(batch, window_tiles, tile) geometry only, never on the scenario's
flow count, and ``count_tiled_lowerings`` holds them to one lowering
per design point across loads and seeds), and the five Pallas kernel
``ops`` wrappers (``rotor_slice_step`` traced with
``force_pallas=True`` so the kernel body, not the CPU ref fast path,
is what the rules walk).
"""
from __future__ import annotations

import dataclasses
import inspect
import os
from typing import Callable, List, Optional, Sequence, Tuple

from repro.staticcheck.findings import Finding

CALLBACK_PRIMITIVES = {
    "pure_callback", "io_callback", "debug_callback", "outside_call",
    "host_callback_call", "infeed", "outfeed",
}


@dataclasses.dataclass
class TracedEntry:
    name: str
    path: str          # repo-relative module path
    line: int
    jaxpr: object      # jax.core.ClosedJaxpr


def _src_location(fn) -> Tuple[str, int]:
    try:
        path = inspect.getsourcefile(fn) or "<unknown>"
        line = inspect.getsourcelines(fn)[1]
    except (OSError, TypeError):
        return "<unknown>", 0
    marker = os.sep + "repro" + os.sep
    if marker in path:
        path = "src" + os.sep + "repro" + os.sep + path.split(marker, 1)[1]
    return path.replace(os.sep, "/"), line


def _entry_specs() -> List[Tuple[str, Callable, Callable]]:
    """(name, traced_callable, args_builder) for every engine entry point.

    Imports live inside so the AST layer stays importable without jax.
    """
    import jax
    import jax.numpy as jnp

    def sd(shape, dt=jnp.float32):
        return jax.ShapeDtypeStruct(shape, dt)

    from repro.netsim import flows_jax, fluid_jax
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.mamba_scan.ops import mamba_scan
    from repro.kernels.moe_gmm.ops import moe_gmm
    from repro.kernels.rglru_scan.ops import rglru_scan
    from repro.kernels.rotor_slice.ops import rotor_slice_step

    return [
        (
            "netsim.fluid_jax._run_batch",
            lambda a, o: fluid_jax._run_batch(a, o, True, 3),
            lambda: (sd((6, 8, 8)), sd((2, 8, 8))),
        ),
        (
            "netsim.fluid_jax._sparse_slice_step",
            lambda *a: fluid_jax._sparse_slice_step(*a, True),
            lambda: (sd((2, 8, 8)), sd((2, 8, 8)), sd((2,)), sd((2,)),
                     sd((8, 2), jnp.int32)),
        ),
        (
            "netsim.fluid_jax._sparse_slice_step_faulted",
            lambda *a: fluid_jax._sparse_slice_step_faulted(*a, True),
            lambda: (
                sd((2, 8, 8)), sd((2, 8, 8)), sd((2,)), sd((2,)), sd((2,)),
                sd((), jnp.int32), sd((8, 2), jnp.int32),
                sd((8, 8), jnp.int32),
                sd((2, 8, 3), jnp.int32), sd((2, 8, 3), jnp.int32),
                sd((2, 8, 3), jnp.int32),
                sd((2, 8), jnp.int32), sd((2, 8), jnp.int32),
                sd((2, 8), jnp.int32),
            ),
        ),
        (
            "netsim.fluid_jax._run_batch_faulted",
            lambda *a: fluid_jax._run_batch_faulted(*a, True, 3, 0),
            lambda: (
                sd((6, 8, 8)), sd((6, 8, 8), jnp.int32),
                sd((8, 8), jnp.int32), sd((2, 8, 8)),
                sd((2, 8, 3), jnp.int32), sd((2, 8, 3), jnp.int32),
                sd((2, 8, 3), jnp.int32),
                sd((2, 8), jnp.int32), sd((2, 8), jnp.int32),
                sd((2, 8), jnp.int32),
            ),
        ),
        (
            "netsim.flows_jax._run_batch",
            lambda *a: flows_jax._run_batch(*a, num_steps=7, trace=False),
            lambda: (
                sd((2, 5)), sd((2, 5), jnp.int32), sd((2, 5), jnp.bool_),
                sd((2,)), sd((2,)), sd((2, 5)), sd((2, 5)),
                sd((2,), jnp.int32), sd((2,), jnp.int32),
                sd((2, 5), jnp.int32), sd((2, 5)), sd((2,)),
            ),
        ),
        (
            "netsim.flows_jax._run_batch_faulted",
            lambda *a: flows_jax._run_batch_faulted(*a, num_steps=7,
                                                    trace=False),
            lambda: (
                sd((2, 5)), sd((2, 5), jnp.int32), sd((2, 5), jnp.bool_),
                sd((2,)), sd((2,)), sd((2, 5)), sd((2, 5)),
                sd((2,), jnp.int32), sd((2,), jnp.int32),
                sd((2, 5), jnp.int32), sd((2, 5)), sd((2,)),
                sd((2, 5), jnp.int32), sd((2, 5), jnp.int32),
                sd((2, 5), jnp.int32), sd((2, 5), jnp.int32),
                sd((2, 7)), sd((2, 7)),
            ),
        ),
        (
            "netsim.flows_jax._run_tiled_chunk",
            lambda *a: flows_jax._run_tiled_chunk(*a, num_steps=7,
                                                  chunk_steps=4),
            lambda: (
                sd((2, 3, 4)), sd((2, 3, 4)), sd((2, 3, 4), jnp.int32),
                sd((2, 3, 4), jnp.bool_), sd((2, 3, 4), jnp.int32),
                sd((2, 3, 4)),
                sd((2,)), sd((2,)), sd((2,)),
                sd((2,), jnp.int32), sd((2,), jnp.int32),
                sd((2, 288), jnp.int32), sd((2,)), sd((2,)), sd((2,)),
                sd((), jnp.int32),
            ),
        ),
        (
            "netsim.flows_jax._run_tiled_chunk_faulted",
            lambda *a: flows_jax._run_tiled_chunk_faulted(*a, num_steps=7,
                                                          chunk_steps=4),
            lambda: (
                sd((2, 3, 4)), sd((2, 3, 4)), sd((2, 3, 4), jnp.int32),
                sd((2, 3, 4), jnp.bool_), sd((2, 3, 4), jnp.int32),
                sd((2, 3, 4)),
                sd((2,)), sd((2,)), sd((2,)),
                sd((2,), jnp.int32), sd((2,), jnp.int32),
                sd((2, 3, 4), jnp.int32), sd((2, 3, 4), jnp.int32),
                sd((2, 3, 4), jnp.int32), sd((2, 3, 4), jnp.int32),
                sd((2, 4)), sd((2, 4)),
                sd((2, 288), jnp.int32), sd((2,)), sd((2,)), sd((2,)),
                sd((), jnp.int32),
            ),
        ),
        (
            "kernels.flash_attention.ops.flash_attention",
            lambda q, k, v: flash_attention(q, k, v, interpret=True),
            lambda: (sd((1, 2, 16, 8)), sd((1, 2, 16, 8)), sd((1, 2, 16, 8))),
        ),
        (
            "kernels.mamba_scan.ops.mamba_scan",
            lambda x, dt, B, C, A, D: mamba_scan(x, dt, B, C, A, D,
                                                 interpret=True),
            lambda: (sd((1, 8, 16)), sd((1, 8, 16)), sd((1, 8, 4)),
                     sd((1, 8, 4)), sd((16, 4)), sd((16,))),
        ),
        (
            "kernels.moe_gmm.ops.moe_gmm",
            lambda h, wg, wu, wd: moe_gmm(h, wg, wu, wd, interpret=True),
            lambda: (sd((2, 8, 16)), sd((2, 16, 32)), sd((2, 16, 32)),
                     sd((2, 32, 16))),
        ),
        (
            "kernels.rglru_scan.ops.rglru_scan",
            lambda a, bx, h0: rglru_scan(a, bx, h0, interpret=True),
            lambda: (sd((1, 8, 16)), sd((1, 8, 16)), sd((1, 16))),
        ),
        (
            "kernels.rotor_slice.ops.rotor_slice_step",
            lambda o, r, d: rotor_slice_step(o, r, d, interpret=True,
                                             force_pallas=True),
            lambda: (sd((2, 8, 8)), sd((2, 8, 8)), sd((8, 2), jnp.int32)),
        ),
    ]


def trace_entrypoints(
    only: Optional[Sequence[str]] = None,
) -> Tuple[List[TracedEntry], List[Finding]]:
    """Abstractly trace every engine entry point under enable_x64."""
    import jax
    from jax.experimental import enable_x64

    entries: List[TracedEntry] = []
    findings: List[Finding] = []
    with enable_x64():
        for name, fn, build_args in _entry_specs():
            if only and not any(o in name for o in only):
                continue
            path, line = _src_location(fn)
            try:
                closed = jax.make_jaxpr(fn)(*build_args())
            except Exception as e:  # a broken trace is itself a finding
                findings.append(Finding(
                    "SC-JAX-TRACE", f"{name} failed to trace: {e!r}",
                    path=path, line=line))
                continue
            entries.append(TracedEntry(name, path, line, closed))
    return entries, findings


def _walk_jaxpr(jaxpr, visit) -> None:
    """Depth-first over eqns, recursing into any sub-jaxpr params."""
    import jax

    def maybe_recurse(v):
        if isinstance(v, jax.core.ClosedJaxpr):
            _walk_jaxpr(v.jaxpr, visit)
        elif isinstance(v, jax.core.Jaxpr):
            _walk_jaxpr(v, visit)
        elif isinstance(v, (tuple, list)):
            for x in v:
                maybe_recurse(x)

    for eqn in jaxpr.eqns:
        visit(eqn)
        for v in eqn.params.values():
            maybe_recurse(v)


def check_float64(entries: Sequence[TracedEntry]) -> List[Finding]:
    """SC-JAX-F64 over traced engines."""
    out: List[Finding] = []
    for entry in entries:
        hits: List[str] = []

        def visit(eqn, hits=hits):
            for v in eqn.outvars:
                dt = getattr(getattr(v, "aval", None), "dtype", None)
                if dt is not None and str(dt) == "float64":
                    hits.append(str(eqn.primitive))

        _walk_jaxpr(entry.jaxpr.jaxpr, visit)
        if hits:
            uniq = sorted(set(hits))
            out.append(Finding(
                "SC-JAX-F64",
                f"{entry.name}: float64 values inside a float32 engine "
                f"(primitives: {', '.join(uniq)}) — weak-type/literal "
                "promotion leak",
                path=entry.path, line=entry.line))
    return out


def check_callbacks(entries: Sequence[TracedEntry]) -> List[Finding]:
    """SC-JAX-CALLBACK over traced engines."""
    out: List[Finding] = []
    for entry in entries:
        hits: List[str] = []

        def visit(eqn, hits=hits):
            if str(eqn.primitive) in CALLBACK_PRIMITIVES:
                hits.append(str(eqn.primitive))

        _walk_jaxpr(entry.jaxpr.jaxpr, visit)
        if hits:
            out.append(Finding(
                "SC-JAX-CALLBACK",
                f"{entry.name}: host callback in hot path "
                f"({', '.join(sorted(set(hits)))})",
                path=entry.path, line=entry.line))
    return out


def count_sweep_lowerings(
    designs: Optional[Sequence[Tuple[int, int, int]]] = None,
    loads: Sequence[float] = (0.1, 0.3),
    seeds: Sequence[int] = (0, 1),
    max_cycles: int = 12,
) -> Tuple[int, int, List[Finding]]:
    """SC-JAX-RECOMPILE: run a representative (k, num_racks, groups) x
    workload x load x seed grid through `netsim/sweep.py` and require at
    most one fresh `_run_batch` lowering per design point (a warm cache
    from earlier calls in-process may make it fewer).

    Returns (new_lowerings, num_design_points, findings)."""
    from repro.netsim import fluid_jax
    from repro.netsim.sweep import DesignPoint, SweepSpec, run_sweep

    designs = designs or ((4, 6, 1), (4, 10, 1))
    spec = SweepSpec(
        designs=tuple(DesignPoint(k=k, num_racks=n, groups=g)
                      for k, n, g in designs),
        workloads=("shuffle", "permutation"),
        loads=tuple(loads),
        seeds=tuple(seeds),
        max_cycles=max_cycles,
    )
    before = fluid_jax._run_batch._cache_size()
    run_sweep(spec)
    new = fluid_jax._run_batch._cache_size() - before
    path, line = _src_location(fluid_jax._run_batch)
    findings: List[Finding] = []
    if new > len(designs):
        findings.append(Finding(
            "SC-JAX-RECOMPILE",
            f"sweep grid of {len(designs)} design points x "
            f"{spec.scenarios_per_design} scenarios compiled {new} "
            "lowerings — the engine must compile once per design-point "
            "shape, not per load/seed",
            path=path, line=line))
    return new, len(designs), findings


def count_fault_lowerings(
    num_draws: int = 2, max_cycles: int = 6,
) -> Tuple[int, List[Finding]]:
    """SC-JAX-RECOMPILE for the fault path: failure timelines are int32
    *data* operands of `fluid_jax._run_batch_faulted` (the per-step 0/1
    masks are rebuilt inside the scan from the global step counter), so
    running several distinct failure draws through one design point must
    add at most one fresh lowering — zero once warm.

    Returns (new_lowerings, findings)."""
    import numpy as np

    from repro.core.topology import build_opera_topology
    from repro.netsim import fluid_jax
    from repro.netsim.faults import FailureSchedule
    from repro.netsim.sweep import DesignPoint

    topo = build_opera_topology(8, 2, seed=0)
    cfg = DesignPoint(k=4, num_racks=8).to_config()
    demand = np.full((8, 8), 1e6)
    np.fill_diagonal(demand, 0.0)
    before = fluid_jax._run_batch_faulted._cache_size()
    for seed in range(num_draws):
        sched = FailureSchedule.draw(
            topo, seed=seed, link_frac=0.1, switch_count=1, onset_step=2)
        fluid_jax.simulate_rotor_bulk_batch(
            cfg, demand[None], topo=topo, max_cycles=max_cycles,
            faults=[sched])
    new = fluid_jax._run_batch_faulted._cache_size() - before
    path, line = _src_location(fluid_jax._run_batch_faulted)
    findings: List[Finding] = []
    if new > 1:
        findings.append(Finding(
            "SC-JAX-RECOMPILE",
            f"{num_draws} failure draws through one design point compiled "
            f"{new} `_run_batch_faulted` lowerings — fault masks are data; "
            "the engine must lower once per design point, never per draw",
            path=path, line=line))
    return new, findings


def count_sparse_lowerings(
    num_cycles: int = 3, num_demands: int = 2,
) -> Tuple[int, List[Finding]]:
    """SC-JAX-RECOMPILE for the sparse engine: its host-side driver
    re-invokes `fluid_jax._sparse_slice_step` once per slice per cycle,
    so a whole run — and every run at the same design point, whatever
    the demand draw — must reuse ONE lowering (slice index tensors are
    same-shape data operands; the global step counter never becomes a
    trace constant).

    Returns (new_lowerings, findings)."""
    import numpy as np

    from repro.core.topology import build_opera_topology
    from repro.netsim import fluid_jax
    from repro.netsim.sweep import DesignPoint

    topo = build_opera_topology(8, 2, seed=0)
    cfg = DesignPoint(k=4, num_racks=8).to_config()
    before = fluid_jax._sparse_slice_step._cache_size()
    rng = np.random.default_rng(0)
    for _ in range(num_demands):
        demand = rng.uniform(0, 1e6, (8, 8))
        np.fill_diagonal(demand, 0.0)
        fluid_jax.simulate_rotor_bulk_batch(
            cfg, demand[None], topo=topo, max_cycles=num_cycles,
            engine="sparse")
    new = fluid_jax._sparse_slice_step._cache_size() - before
    path, line = _src_location(fluid_jax._sparse_slice_step)
    findings: List[Finding] = []
    if new > 1:
        findings.append(Finding(
            "SC-JAX-RECOMPILE",
            f"{num_demands} sparse-engine runs x {num_cycles} cycles x "
            f"{topo.num_slices} slices at one design point compiled {new} "
            "`_sparse_slice_step` lowerings — slice index tensors are "
            "data; the per-step program must lower once per design-point "
            "shape, never per slice or per run",
            path=path, line=line))
    return new, findings


def count_tiled_lowerings(
    loads: Sequence[float] = (0.05, 0.2),
    seeds: Sequence[int] = (0, 1),
) -> Tuple[int, List[Finding]]:
    """SC-JAX-RECOMPILE for the tiled flow engine: the streamed chunk
    program's shapes depend only on the (batch, window_tiles, tile,
    chunk_steps) geometry — the scenario's total flow count, load and
    seed are *data*.  Running a small load x seed grid through
    `simulate_grid(engine="tiled")` twice must add at most one fresh
    `_run_tiled_chunk` lowering, and the second (warm) run must add
    zero.

    The window is kept wide enough that capacity growth never triggers
    a second geometry in this probe (growth lowerings are legitimate
    but would muddy the once-per-design-point count).

    Returns (new_lowerings, findings)."""
    from repro.netsim import flows_jax

    kw = dict(
        num_hosts=16, horizon_s=0.06, dt_s=5e-4, tail_s=0.04,
        tile_size=64, window_tiles=8, chunk_steps=32,
    )
    before = flows_jax._run_tiled_chunk._cache_size()
    flows_jax.simulate_grid(("opera",), ("websearch",), tuple(loads),
                            seeds=tuple(seeds), engine="tiled", **kw)
    cold = flows_jax._run_tiled_chunk._cache_size() - before
    flows_jax.simulate_grid(("opera",), ("websearch",), tuple(loads),
                            seeds=tuple(seeds), engine="tiled", **kw)
    warm = flows_jax._run_tiled_chunk._cache_size() - before - cold
    new = cold + warm
    path, line = _src_location(flows_jax._run_tiled_chunk)
    findings: List[Finding] = []
    if cold > 1 or warm > 0:
        findings.append(Finding(
            "SC-JAX-RECOMPILE",
            f"{len(loads)}x{len(seeds)} tiled flow grid compiled {cold} "
            f"cold + {warm} warm `_run_tiled_chunk` lowerings — chunk "
            "shapes are (batch, window, tile) geometry only; loads and "
            "seeds are data and must never trigger fresh lowerings",
            path=path, line=line))
    return new, findings
