"""Slice-stepped fluid simulator for rotor fabrics + static comparisons.

Bulk traffic in Opera/RotorNet is fundamentally fluid at the slice
timescale: buffers drain over direct circuits (plus RotorLB's two-hop
relay when capacity is spare and demand is skewed).  This engine steps
topology slices, moving bytes over live matchings — faithful to §4.2.2
and sufficient for every bulk-side figure (8, 10, 12) of the paper.

This module is the **numpy reference oracle**.  The per-slice recurrence
(`rotor_slice_step`) is a deterministic, fully-vectorized function of
the dense slice adjacency exported by `OperaTopology.matching_tensor`;
the batched jnp engine in `netsim/fluid_jax.py` implements *identical*
math (lockstep-tested by tests/test_netsim_jax.py; the SC-AST-LOCKSTEP
staticcheck rule flags diffs touching one file without the other) and
is the one the benchmark sweeps run on.  That engine now carries two
interchangeable backends — the dense scan mirroring this oracle
term-for-term, and a permutation-sparse gather/scatter form
(`kernels/rotor_slice/`, fed by `OperaTopology.
matching_index_tensor()`) that reaches the k >= 32 Appendix-B design
points — but *this* dense numpy recurrence stays the single source of
truth both parity-test against.  RotorLB's VLB spreading is modeled as a
proportional fluid allocation: each rack offers its queued backlog to
all live partners in proportion to their spare circuit room (rather
than the earlier greedy top-4 heuristic), which is both closer to a
fluid limit of RotorLB's per-slot offers and expressible as one
matmul — the property that lets the jnp engine scan it.

Static networks are served by a max-min fluid share over their fixed
graphs (expander) or their oversubscription bottleneck (folded Clos).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.opera_paper import OperaNetConfig
from repro.core.schedule import cycle_timing, slice_capacity_bytes
from repro.core.topology import OperaTopology, build_opera_topology


@dataclasses.dataclass
class RotorFluidResult:
    finished_frac: List[float]          # per slice-step, fraction of bytes done
    time_us: List[float]
    fct_99_ms: float
    fct_mean_ms: float
    throughput_gbps: float              # aggregate goodput
    wire_bytes: float                   # total bytes that crossed links
    goodput_bytes: float                # demand bytes delivered
    slices_run: int
    blackholed_bytes: float = 0.0       # sent into undetected-dead circuits

    @property
    def bandwidth_tax(self) -> float:
        return self.wire_bytes / max(self.goodput_bytes, 1.0) - 1.0


def rotor_slice_step(
    own: np.ndarray,
    relay: np.ndarray,
    adj_cap: np.ndarray,
    vlb: bool = True,
) -> Tuple[np.ndarray, np.ndarray, float, float]:
    """One topology slice of the rotor fluid recurrence.

    `adj_cap[i, j]` is the byte budget of the i-j circuit this slice
    (0 where dark).  Three phases, each a dense array op:

      1. direct drain: own traffic for the connected partner;
      2. relay drain: 2-hop traffic now one hop from its destination;
      3. RotorLB VLB: leftover circuit room carries queued own traffic
         to the partner as relay (the taxed first hop), source backlog
         offered proportionally and partner room filled proportionally —
         ``relay += (room / row_room).T @ take`` in one matmul.

    This function is the semantic contract for the batched jnp engine
    (`fluid_jax._slice_step` implements the same math); change the two
    together.  Returns (own, relay, delivered_bytes, vlb_first_hop_bytes).
    """
    send_own = np.minimum(own, adj_cap)
    own = own - send_own
    room = adj_cap - send_own
    send_relay = np.minimum(relay, room)
    relay = relay - send_relay
    room = room - send_relay
    delivered = float(send_own.sum() + send_relay.sum())

    moved = 0.0
    if vlb:
        # backlog eligible for spreading: not deliverable directly this
        # slice (a live pair's residual would arrive anyway, and relaying
        # it to its own destination would strand bytes on the diagonal)
        elig = np.where(adj_cap > 0, 0.0, own)
        q = elig.sum(1)                       # spreadable backlog per rack
        r = room.sum(1)                       # spare circuit room per rack
        t = np.minimum(q, r)                  # bytes rack s may spread
        take = elig * np.divide(t, q, out=np.zeros_like(q), where=q > 0)[:, None]
        share = room * np.divide(
            np.ones_like(r), r, out=np.zeros_like(r), where=r > 0
        )[:, None]                            # partner share of s's spread
        own = own - take
        relay = relay + share.T @ take
        moved = float(t.sum())                # first hop of the 2-hop path
    return own, relay, delivered, moved


def rotor_slice_step_faulted(
    own: np.ndarray,
    relay: np.ndarray,
    adj_cap: np.ndarray,
    e_real: np.ndarray,
    e_known: np.ndarray,
    tor_real: np.ndarray,
    tor_known: np.ndarray,
    pair_dead: np.ndarray,
    vlb: bool = True,
) -> Tuple[np.ndarray, np.ndarray, float, float, float]:
    """`rotor_slice_step` under failure masks (from `faults.step_masks`).

    Graceful-degradation semantics (§3.4, Fig. 11):

      * offered capacity excludes *detected*-dead edges and physically
        dead source ToRs: ``cap = adj * (1 - e_known) * (1 - tor_real)``
        on the row side — direct traffic re-queues around known holes;
      * bytes committed to an edge that is dead but not yet detected
        (the hello-protocol lag) consume the wire slot and are lost in
        flight: they stay queued at the source (retransmit) and count
        toward ``blackholed``;
      * VLB spreads only backlog for destinations not known-dead, over
        believed-live room; the blackholed fraction of the spread is
        refunded to the source queue;
      * relayed bytes whose direct circuit to the destination is known
        dead for the whole cycle (``pair_dead``, one serving switch per
        pair) re-join the spread — RotorLB forwards non-local traffic
        onward rather than hold it for a circuit that will not come.

    With all-zero masks every expression reduces to the exact
    failure-free arithmetic (x*1.0 and x+0.0 are IEEE-exact), so
    `FailureSchedule.empty()` is bit-identical to `rotor_slice_step`.
    `fluid_jax._slice_step_faulted` implements the same math in jnp —
    change the two together.  Returns (own, relay, delivered_bytes,
    vlb_first_hop_bytes, blackholed_bytes).
    """
    cap = adj_cap * (1.0 - e_known) * (1.0 - tor_real)[:, None]
    arrive = 1.0 - e_real
    send_own = np.minimum(own, cap)
    own = own - send_own * arrive
    room = cap - send_own
    send_relay = np.minimum(relay, room)
    relay = relay - send_relay * arrive
    room = room - send_relay
    delivered = float((send_own * arrive).sum() + (send_relay * arrive).sum())
    attempted = float(send_own.sum() + send_relay.sum())
    blackholed = attempted - delivered

    moved = 0.0
    if vlb:
        dst_ok = 1.0 - tor_known
        elig = np.where(cap > 0, 0.0, own * dst_ok[None, :])
        relig = relay * pair_dead * dst_ok[None, :]   # stuck relay re-spreads
        q = elig.sum(1) + relig.sum(1)
        r = room.sum(1)
        t = np.minimum(q, r)
        frac = np.divide(t, q, out=np.zeros_like(q), where=q > 0)[:, None]
        take = elig * frac
        rtake = relig * frac
        share = room * np.divide(
            np.ones_like(r), r, out=np.zeros_like(r), where=r > 0
        )[:, None]
        lost = (share * e_real).sum(1)        # spread fraction that blackholes
        own = own - take + take * lost[:, None]
        relay = relay - rtake + rtake * lost[:, None]
        relay = relay + (share * arrive).T @ (take + rtake)
        lost_bytes = float(((take + rtake).sum(1) * lost).sum())
        moved = float(t.sum()) - lost_bytes   # first hops that truly crossed
        blackholed += lost_bytes
    return own, relay, delivered, moved, blackholed


def simulate_rotor_bulk(
    cfg: OperaNetConfig,
    demand: np.ndarray,            # rack->rack bytes (bulk class)
    vlb: bool = True,
    max_cycles: int = 400,
    topo: Optional[OperaTopology] = None,
    seed: int = 0,
    faults=None,                   # Optional[faults.FailureSchedule]
    paced_cycles: int = 0,
) -> RotorFluidResult:
    n = cfg.num_racks
    topo = topo or build_opera_topology(n, cfg.u, seed=seed, groups=cfg.groups)
    t = cycle_timing(cfg)
    cap = slice_capacity_bytes(cfg, t)       # bytes/link/slice
    adj_caps = topo.matching_tensor().astype(np.float64) * cap

    masks = None
    if faults is not None and faults.events:
        # Event-less schedules skip mask compilation and run the
        # original failure-free step — mirrors `fluid_jax`'s dispatch,
        # which keeps `FailureSchedule.empty()` bit-identical there.
        from repro.netsim.faults import compile_fault_masks, step_masks

        masks = compile_fault_masks(topo, faults)

    own = demand.astype(np.float64).copy()
    total = own.sum()
    inject = None
    if paced_cycles:
        # paced offering: demand arrives in equal installments at the
        # first `paced_cycles` cycle starts instead of all at t=0
        inject = own * (1.0 / paced_cycles)
        own = np.zeros_like(own)
    relay = np.zeros_like(own)
    done = 0.0
    wire = 0.0
    blackholed = 0.0
    finished, times = [], []

    steps = 0
    for step in range(max_cycles * topo.num_slices):
        sl = step % topo.num_slices
        if inject is not None and sl == 0 and step // topo.num_slices < paced_cycles:
            own = own + inject
        if masks is None:
            own, relay, delivered, moved = rotor_slice_step(
                own, relay, adj_caps[sl], vlb
            )
        else:
            e_real, e_known, tor_real, tor_known, pair_dead = step_masks(
                masks, 0, step, sl)
            own, relay, delivered, moved, blk = rotor_slice_step_faulted(
                own, relay, adj_caps[sl],
                e_real, e_known, tor_real, tor_known, pair_dead, vlb,
            )
            blackholed += blk
        done += delivered
        wire += delivered + moved
        steps += 1
        finished.append(done / max(total, 1.0))
        times.append((step + 1) * t.slice_us)
        if done >= total * 0.99999:
            break

    arr = np.array(finished)
    tms = np.array(times) / 1e3
    fct99 = float(tms[np.searchsorted(arr, 0.99)]) if arr[-1] >= 0.99 else float("inf")
    fct_mean = float(np.interp(0.5, arr, tms))
    dur_s = times[-1] * 1e-6
    return RotorFluidResult(
        finished_frac=finished,
        time_us=times,
        fct_99_ms=fct99,
        fct_mean_ms=fct_mean,
        throughput_gbps=done * 8 / dur_s / 1e9,
        wire_bytes=wire,
        goodput_bytes=done,
        slices_run=steps,
        blackholed_bytes=blackholed,
    )


# ---------------- static comparison networks --------------------------------


@dataclasses.dataclass
class StaticFluidResult:
    fct_99_ms: float
    throughput_gbps: float
    wire_bytes: float
    goodput_bytes: float

    @property
    def bandwidth_tax(self) -> float:
        return self.wire_bytes / max(self.goodput_bytes, 1.0) - 1.0


def simulate_expander_bulk(
    adj: np.ndarray,
    demand: np.ndarray,
    link_rate_gbps: float,
    dt_us: float = 100.0,
    max_steps: int = 1_000_000,
) -> StaticFluidResult:
    """Max-min fluid over a static expander with shortest-path routing.

    Every byte consumes `hops` link-slots (the bandwidth tax); service is
    a per-source fair share of each link.  We approximate max-min by
    uniform sharing over the flows crossing each link, iterated per step.
    """
    from repro.core.routing import bfs_next_hop

    n = adj.shape[0]
    dist, nxt = bfs_next_hop(adj)
    # link loads: route demand along shortest paths, precompute per-pair path
    paths: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for s in range(n):
        for d in range(n):
            if s == d or demand[s, d] <= 0:
                continue
            path = []
            cur = s
            while cur != d:
                h = nxt[cur, d]
                if h < 0:
                    break
                path.append((cur, h))
                cur = h
            paths[(s, d)] = path

    left = demand.astype(np.float64).copy()
    cap_per_step = link_rate_gbps * 1e9 / 8 * dt_us * 1e-6
    total = left.sum()
    done, wire, steps = 0.0, 0.0, 0
    done_hist, t_hist = [], []
    active = {k for k, v in paths.items() if left[k] > 0}
    while active and steps < max_steps:
        # count flows per link
        link_flows: Dict[Tuple[int, int], int] = {}
        for k in active:
            for e in paths[k]:
                link_flows[e] = link_flows.get(e, 0) + 1
        newly_done = []
        for k in active:
            share = min(cap_per_step / link_flows[e] for e in paths[k])
            mv = min(left[k], share)
            left[k] -= mv
            done += mv
            wire += mv * len(paths[k])
            if left[k] <= 0:
                newly_done.append(k)
        for k in newly_done:
            active.remove(k)
        steps += 1
        done_hist.append(done / max(total, 1.0))
        t_hist.append(steps * dt_us / 1e3)
        if done >= total * 0.99999:
            break
    arr = np.array(done_hist)
    fct99 = float(np.array(t_hist)[np.searchsorted(arr, 0.99)]) if arr[-1] >= 0.99 else float("inf")
    dur_s = steps * dt_us * 1e-6
    return StaticFluidResult(
        fct_99_ms=fct99,
        throughput_gbps=done * 8 / dur_s / 1e9,
        wire_bytes=wire,
        goodput_bytes=done,
    )


def simulate_clos_bulk(
    num_hosts: int,
    demand: np.ndarray,          # rack-level
    link_rate_gbps: float,
    oversubscription: float = 3.0,
) -> StaticFluidResult:
    """Folded Clos as its two binding constraints: per-host NIC rate and
    the core bottleneck (aggregate inter-rack capacity = hosts*rate/M)."""
    total = demand.sum()
    core_gbps = num_hosts * link_rate_gbps / oversubscription
    # per-rack egress also bounded by d*rate
    num_racks = demand.shape[0]
    hosts_per_rack = num_hosts // num_racks
    rack_out = demand.sum(1).max()
    rack_in = demand.sum(0).max()
    egress_gbps = hosts_per_rack * link_rate_gbps
    t_core = total * 8 / (core_gbps * 1e9)
    t_edge = max(rack_out, rack_in) * 8 / (egress_gbps * 1e9)
    dur = max(t_core, t_edge, 1e-9)
    return StaticFluidResult(
        fct_99_ms=dur * 1e3,
        throughput_gbps=total * 8 / dur / 1e9,
        wire_bytes=total,  # direct routing: no tax
        goodput_bytes=total,
    )
