"""Slice-stepped fluid simulator for rotor fabrics + static comparisons.

Bulk traffic in Opera/RotorNet is fundamentally fluid at the slice
timescale: buffers drain over direct circuits (plus RotorLB's two-hop
relay when capacity is spare and demand is skewed).  This engine steps
topology slices, moving bytes over live matchings — faithful to §4.2.2
and sufficient for every bulk-side figure (8, 10, 12) of the paper.

Static networks are served by a max-min fluid share over their fixed
graphs (expander) or their oversubscription bottleneck (folded Clos).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.opera_paper import OperaNetConfig
from repro.core.schedule import cycle_timing
from repro.core.topology import OperaTopology, build_opera_topology


@dataclasses.dataclass
class RotorFluidResult:
    finished_frac: List[float]          # per slice-step, fraction of bytes done
    time_us: List[float]
    fct_99_ms: float
    fct_mean_ms: float
    throughput_gbps: float              # aggregate goodput
    wire_bytes: float                   # total bytes that crossed links
    goodput_bytes: float                # demand bytes delivered
    slices_run: int

    @property
    def bandwidth_tax(self) -> float:
        return self.wire_bytes / max(self.goodput_bytes, 1.0) - 1.0


def simulate_rotor_bulk(
    cfg: OperaNetConfig,
    demand: np.ndarray,            # rack->rack bytes (bulk class)
    vlb: bool = True,
    max_cycles: int = 400,
    topo: Optional[OperaTopology] = None,
    seed: int = 0,
) -> RotorFluidResult:
    n = cfg.num_racks
    topo = topo or build_opera_topology(n, cfg.u, seed=seed, groups=cfg.groups)
    t = cycle_timing(cfg)
    slice_s = t.slice_us * 1e-6
    cap = cfg.link_rate_gbps * 1e9 / 8 * slice_s * t.duty_cycle  # bytes/link/slice

    own = demand.astype(np.float64).copy()
    relay = np.zeros_like(own)
    total = own.sum()
    done = 0.0
    wire = 0.0
    finished, times = [], []
    per_pair_left = own.copy()

    steps = 0
    for step in range(max_cycles * topo.num_slices):
        tslice = step % topo.num_slices
        for _, p in topo.live_matchings(tslice):
            idx = np.arange(n)
            mask = p != idx
            srcs = idx[mask]
            dsts = p[mask]
            # 1) direct: own traffic for the connected partner
            send_own = np.minimum(own[srcs, dsts], cap)
            own[srcs, dsts] -= send_own
            # 2) relayed traffic now one hop from its destination
            room = cap - send_own
            send_relay = np.minimum(relay[srcs, dsts], room)
            relay[srcs, dsts] -= send_relay
            room -= send_relay
            delivered = send_own + send_relay
            done += delivered.sum()
            wire += (send_own + send_relay).sum()
            per_pair_left[srcs, dsts] = np.maximum(
                per_pair_left[srcs, dsts] - send_own, 0.0
            )
            # 3) RotorLB VLB: spare capacity spreads own queued traffic to
            #    the partner as a relay (delivered next cycle) — only when
            #    the partner's relay queue isn't already deep (fairness).
            if vlb:
                for k in range(len(srcs)):
                    r = room[k]
                    if r <= 0:
                        continue
                    s, m = srcs[k], dsts[k]
                    row = own[s]
                    # spread from the largest backlogs first
                    for dd in np.argsort(row)[::-1][:4]:
                        if row[dd] <= 0 or dd == m or r <= 0:
                            continue
                        mv = min(row[dd], r)
                        own[s, dd] -= mv
                        relay[m, dd] += mv
                        wire += mv  # first hop of the 2-hop path (the tax)
                        r -= mv
                    room[k] = r
        steps += 1
        finished.append(done / max(total, 1.0))
        times.append((step + 1) * t.slice_us)
        if done >= total * 0.99999:
            break

    arr = np.array(finished)
    tms = np.array(times) / 1e3
    fct99 = float(tms[np.searchsorted(arr, 0.99)]) if arr[-1] >= 0.99 else float("inf")
    fct_mean = float(np.interp(0.5, arr, tms))
    dur_s = times[-1] * 1e-6
    return RotorFluidResult(
        finished_frac=finished,
        time_us=times,
        fct_99_ms=fct99,
        fct_mean_ms=fct_mean,
        throughput_gbps=done * 8 / dur_s / 1e9,
        wire_bytes=wire,
        goodput_bytes=done,
        slices_run=steps,
    )


# ---------------- static comparison networks --------------------------------


@dataclasses.dataclass
class StaticFluidResult:
    fct_99_ms: float
    throughput_gbps: float
    wire_bytes: float
    goodput_bytes: float

    @property
    def bandwidth_tax(self) -> float:
        return self.wire_bytes / max(self.goodput_bytes, 1.0) - 1.0


def simulate_expander_bulk(
    adj: np.ndarray,
    demand: np.ndarray,
    link_rate_gbps: float,
    dt_us: float = 100.0,
    max_steps: int = 1_000_000,
) -> StaticFluidResult:
    """Max-min fluid over a static expander with shortest-path routing.

    Every byte consumes `hops` link-slots (the bandwidth tax); service is
    a per-source fair share of each link.  We approximate max-min by
    uniform sharing over the flows crossing each link, iterated per step.
    """
    from repro.core.routing import bfs_next_hop

    n = adj.shape[0]
    dist, nxt = bfs_next_hop(adj)
    # link loads: route demand along shortest paths, precompute per-pair path
    paths: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for s in range(n):
        for d in range(n):
            if s == d or demand[s, d] <= 0:
                continue
            path = []
            cur = s
            while cur != d:
                h = nxt[cur, d]
                if h < 0:
                    break
                path.append((cur, h))
                cur = h
            paths[(s, d)] = path

    left = demand.astype(np.float64).copy()
    cap_per_step = link_rate_gbps * 1e9 / 8 * dt_us * 1e-6
    total = left.sum()
    done, wire, steps = 0.0, 0.0, 0
    done_hist, t_hist = [], []
    active = {k for k, v in paths.items() if left[k] > 0}
    while active and steps < max_steps:
        # count flows per link
        link_flows: Dict[Tuple[int, int], int] = {}
        for k in active:
            for e in paths[k]:
                link_flows[e] = link_flows.get(e, 0) + 1
        newly_done = []
        for k in active:
            share = min(cap_per_step / link_flows[e] for e in paths[k])
            mv = min(left[k], share)
            left[k] -= mv
            done += mv
            wire += mv * len(paths[k])
            if left[k] <= 0:
                newly_done.append(k)
        for k in newly_done:
            active.remove(k)
        steps += 1
        done_hist.append(done / max(total, 1.0))
        t_hist.append(steps * dt_us / 1e3)
        if done >= total * 0.99999:
            break
    arr = np.array(done_hist)
    fct99 = float(np.array(t_hist)[np.searchsorted(arr, 0.99)]) if arr[-1] >= 0.99 else float("inf")
    dur_s = steps * dt_us * 1e-6
    return StaticFluidResult(
        fct_99_ms=fct99,
        throughput_gbps=done * 8 / dur_s / 1e9,
        wire_bytes=wire,
        goodput_bytes=done,
    )


def simulate_clos_bulk(
    num_hosts: int,
    demand: np.ndarray,          # rack-level
    link_rate_gbps: float,
    oversubscription: float = 3.0,
) -> StaticFluidResult:
    """Folded Clos as its two binding constraints: per-host NIC rate and
    the core bottleneck (aggregate inter-rack capacity = hosts*rate/M)."""
    total = demand.sum()
    core_gbps = num_hosts * link_rate_gbps / oversubscription
    # per-rack egress also bounded by d*rate
    num_racks = demand.shape[0]
    hosts_per_rack = num_hosts // num_racks
    rack_out = demand.sum(1).max()
    rack_in = demand.sum(0).max()
    egress_gbps = hosts_per_rack * link_rate_gbps
    t_core = total * 8 / (core_gbps * 1e9)
    t_edge = max(rack_out, rack_in) * 8 / (egress_gbps * 1e9)
    dur = max(t_core, t_edge, 1e-9)
    return StaticFluidResult(
        fct_99_ms=dur * 1e3,
        throughput_gbps=total * 8 / dur / 1e9,
        wire_bytes=total,  # direct routing: no tax
        goodput_bytes=total,
    )
