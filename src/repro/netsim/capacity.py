"""Analytic capacity model (§2, §5.3, §5.6, Appendix A).

Per-host-link normalized capacities for each cost-equivalent network.
One transport-efficiency constant eta_indirect is calibrated so the
u=7 expander saturates at the paper's ~25 % Websearch load; everything
else (Opera's ~10 %, the 60 %-capacity/41 %-more-tax decomposition,
Fig. 12's alpha crossovers) then follows from the model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

# transport efficiency of multi-hop traffic (NDP over expander paths):
# calibrated once against the expander's published 25 % saturation.
ETA_INDIRECT = 0.42
ETA_DIRECT = 0.90


@dataclasses.dataclass(frozen=True)
class NetPoint:
    name: str
    u: float              # uplinks per ToR
    d: float              # hosts per ToR
    avg_hops: float       # mean ToR-to-ToR path length
    duty: float = 1.0


OPERA_648_PT = NetPoint("opera-648", u=5.0, d=6.0, avg_hops=3.34, duty=0.985)
# while one of 6 switches reconfigures, 5 uplinks are usable
EXPANDER_650_PT = NetPoint("expander-650", u=7.0, d=5.0, avg_hops=2.36)
CLOS_648_PT = NetPoint("clos-3to1", u=4.0, d=12.0, avg_hops=1.0)  # logical


def latency_capacity(p: NetPoint) -> float:
    """Admissible low-latency (multi-hop) load as a fraction of host rate."""
    return ETA_INDIRECT * p.duty * p.u / (p.d * p.avg_hops)


def bulk_capacity_opera(p: NetPoint) -> float:
    """Tax-free direct capacity per host for bulk (one-hop circuits)."""
    return ETA_DIRECT * p.duty * p.u / p.d


def clos_capacity(oversub: float) -> float:
    return ETA_DIRECT / oversub


def summary_648() -> Dict[str, float]:
    op, ex = OPERA_648_PT, EXPANDER_650_PT
    return dict(
        opera_latency_load=latency_capacity(op),
        expander_load=latency_capacity(ex),
        clos_load=clos_capacity(3.0),
        opera_bulk_load=bulk_capacity_opera(op),
        # §5.3 decomposition: Opera has (5/6)/(7/5)=0.60 of the expander's
        # in-fabric capacity and consumes avg_hops-ratio more wire bytes
        # per delivered byte ("an additional 41% bandwidth tax")
        capacity_ratio=(op.u / op.d) / (ex.u / ex.d),
        extra_tax=op.avg_hops / ex.avg_hops - 1.0,
    )


# ---------------- Fig. 12: cost-normalized throughput vs alpha -------------


def fig12_model(alpha: float, workload: str, k: int = 24) -> Dict[str, float]:
    """Throughput (fraction of host rate) for Opera vs cost-equivalent
    static networks at Opera-port relative cost `alpha`.

    Cost normalization (Appendix A): at cost parity a static network can
    deploy `alpha` x the core ports of Opera; we scale the expander's
    uplinks and the Clos's effective over-subscription accordingly.
    """
    u0, d0 = k / 2.0, k / 2.0
    op = NetPoint("opera", u=u0 - 1, d=d0, avg_hops=3.3, duty=0.985)
    # Appendix A at cost parity: the expander re-splits its k-radix ToR so
    # that u/d ~ alpha (vs Opera's 1:1); the folded Clos's
    # over-subscription is F = 4/alpha (alpha = 2(T-1)/F at T = 3 tiers).
    u_exp = alpha * k / (1.0 + alpha)
    ex = NetPoint("expander", u=u_exp, d=max(k - u_exp, 1.0), avg_hops=2.4)
    clos = clos_capacity(max(4.0 / alpha, 1.0))
    # bulk over taxed expander paths runs at the fluid (congested) transport
    # efficiency — between the latency-pool calibration and ideal.
    ETA_BULK_INDIRECT = 0.6
    exp_taxed = ETA_BULK_INDIRECT * ex.u / (ex.d * ex.avg_hops)

    if workload == "shuffle":
        opera = bulk_capacity_opera(op)          # all-to-all: every pair's
        exp = exp_taxed                          # circuit used every cycle
    elif workload == "hotrack":
        # one rack pair: direct circuits alone give u/N of a link; RotorLB
        # VLB floods all uplinks at 100 % tax instead.
        opera = ETA_DIRECT * op.duty * op.u / (2.0 * op.d)
        exp = ETA_BULK_INDIRECT * ex.u / (ex.d * 2.0)  # VLB there too
    elif workload == "skew":
        # 20 % of racks active: substantial direct time + VLB remainder
        opera = ETA_DIRECT * op.duty * op.u / (1.3 * op.d)
        exp = exp_taxed
    elif workload == "permutation":
        # one destination per rack -> its direct circuit is live only u/N
        # of the cycle: VLB carries the load (the paper's RotorLB skew case)
        opera = ETA_DIRECT * op.duty * op.u / (2.0 * op.d)
        exp = exp_taxed
    else:
        raise ValueError(workload)
    return dict(alpha=alpha, opera=min(opera, 1.0), expander=min(exp, 1.0),
                clos=min(clos, 1.0))


def crossover_alpha(workload: str, k: int = 24) -> float:
    """Smallest alpha at which a static network beats Opera."""
    for a in np.arange(1.0, 4.01, 0.05):
        r = fig12_model(float(a), workload, k)
        if max(r["expander"], r["clos"]) > r["opera"]:
            return float(a)
    return 4.0
