"""Poisson flow-level simulator with processor sharing (Figs. 7, 9, 10).

Flows arrive Poisson at a target load (fraction of aggregate host-link
capacity), draw sizes from a published distribution, and are served by
per-class capacity pools:

  Opera:   <15 MB -> latency pool (immediate, multi-hop, taxed);
           >=15 MB -> bulk pool (direct circuits, tax-free) after a
           uniform wait for the right slice (<= one cycle).
  static:  a single pool (expander: taxed multi-hop; Clos: direct but
           core-capacity-bound).  Priority queuing for short flows is
           modeled by serving the latency class first from the shared pool.

This is the level of abstraction at which the paper's saturation loads
and FCT-vs-load trends are determined; packet/transport micro-behavior
is folded into the calibrated pool capacities (netsim/capacity.py).

This module is the *numpy oracle*: `build_scenario` freezes a scenario's
arrivals/sizes/pools into a `FlowScenario`, `_oracle_steps` runs the
fixed-dt processor-sharing recurrence on it, and `finalize` turns raw
completion steps into a `FlowSimResult` (`finalize_streamed` does the
same from log-binned completion histograms — the form the tiled
streaming engine accumulates on device).  The batched JAX engine
(`netsim/flows_jax.py`) consumes the *same* `FlowScenario` and
`finalize`, and its `_flow_step` mirrors `_oracle_steps`'s per-step math
exactly — change the two together (lockstep-tested by
tests/test_flows_jax.py; the SC-AST-LOCKSTEP staticcheck rule flags
diffs touching one file without the other).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.netsim import capacity as C
from repro.netsim.workloads import mean_flow_size, sample_flow_sizes

BULK_CUTOFF = 15e6
NETWORKS = ("opera", "expander", "clos", "rotornet")

# ---------------- streamed FCT histograms ------------------------------
# Log-spaced completion-time bins shared by the JAX engines' on-device
# accumulators and the host-side quantile reconstruction.  96 bins over
# [0.01 ms, 100 s] is ~1.19x per bin, so a histogram-derived percentile
# is within one bin (< 19% relative) of the exact order statistic —
# the resolution the paper's log-scale FCT figures plot at.  Flows
# outside the range land in the edge bins (clipped, never dropped), so
# per-class counts stay exact.
FCT_HIST_LO_MS = 1e-2
FCT_HIST_HI_MS = 1e5
FCT_HIST_BINS = 96
NUM_FCT_CLASSES = 3            # small (<100 KB) / mid / large (>= cutoff)
FCT_HIST_LO_LOG2 = float(np.log2(FCT_HIST_LO_MS))
FCT_BIN_LOG2_WIDTH = float(
    (np.log2(FCT_HIST_HI_MS) - np.log2(FCT_HIST_LO_MS)) / FCT_HIST_BINS
)


def fct_hist_edges() -> np.ndarray:
    """(FCT_HIST_BINS + 1,) bin edges in ms."""
    return 2.0 ** (
        FCT_HIST_LO_LOG2 + np.arange(FCT_HIST_BINS + 1) * FCT_BIN_LOG2_WIDTH
    )


def fct_class_id(sizes: np.ndarray) -> np.ndarray:
    """(n,) int32 size-class index: 0 small, 1 mid, 2 large."""
    return np.where(
        sizes >= BULK_CUTOFF, 2, np.where(sizes >= 100e3, 1, 0)
    ).astype(np.int32)


def fct_bin(fct_ms: np.ndarray) -> np.ndarray:
    """(n,) histogram bin index per completion time — the host reference
    for the device-side binning in `flows_jax._hist_accumulate`."""
    with np.errstate(divide="ignore"):
        b = np.floor(
            (np.log2(np.asarray(fct_ms, np.float64)) - FCT_HIST_LO_LOG2)
            / FCT_BIN_LOG2_WIDTH
        )
    return np.clip(b, 0, FCT_HIST_BINS - 1).astype(np.int64)


@dataclasses.dataclass
class FlowSimResult:
    load: float
    fct_p99_ms_small: float      # flows < 100 KB
    fct_p99_ms_mid: float        # 100 KB .. 15 MB
    fct_p99_ms_large: float      # >= 15 MB
    fct_mean_ms: float
    admitted: bool               # backlog stable at this load?
    finished_frac: float
    backlog_frac: float = 0.0    # unserved fraction at end of arrivals


@dataclasses.dataclass
class FlowScenario:
    """One frozen (network, workload, load, seed) draw: everything the
    fixed-dt recurrence needs, with times pre-discretized to step
    indices so the numpy oracle and the JAX engine see bit-identical
    activation schedules."""

    network: str
    workload: str
    load: float
    seed: int
    horizon_s: float
    dt_s: float
    tail_s: float
    num_hosts: int
    link_gbps: float
    arr: np.ndarray              # (n,) arrival time [s]
    sizes: np.ndarray            # (n,) flow size [bytes]
    start_step: np.ndarray       # (n,) first step the flow is servable
    is_bulk: np.ndarray          # (n,) bool: bulk-pool class
    lat_pool_Bps: float          # latency-class pool [bytes/s]
    bulk_pool_Bps: float         # bulk-class pool [bytes/s]
    # Optional fault projection (faults.apply_flow_faults) — all six are
    # set together.  Windows are [start, end) step intervals per flow:
    # a *blackholed* flow keeps consuming its pool share with zero
    # progress (retransmits into a dead circuit, pre-detection); a
    # *frozen* flow (behind a detected-dead ToR) gets no share and no
    # progress until recovery, then retries.  Scales are (steps,)
    # per-step pool-capacity multipliers for detected capacity loss.
    blk_start: Optional[np.ndarray] = None   # (n,) int32
    blk_end: Optional[np.ndarray] = None     # (n,) int32
    frz_start: Optional[np.ndarray] = None   # (n,) int32
    frz_end: Optional[np.ndarray] = None     # (n,) int32
    lat_scale: Optional[np.ndarray] = None   # (steps,) float64
    bulk_scale: Optional[np.ndarray] = None  # (steps,) float64

    @property
    def has_faults(self) -> bool:
        return self.blk_start is not None

    @property
    def num_flows(self) -> int:
        return int(self.arr.size)

    @property
    def nic_Bps(self) -> float:
        return self.link_gbps * 1e9 / 8.0

    @property
    def steps(self) -> int:
        return int(self.horizon_s / self.dt_s) + int(self.tail_s / self.dt_s)

    @property
    def mid_step(self) -> int:
        """First step at which t >= horizon/2 (backlog snapshot)."""
        return int(np.ceil(self.horizon_s / 2 / self.dt_s))

    @property
    def end_step(self) -> int:
        """First step at which t >= horizon (backlog snapshot)."""
        return int(np.ceil(self.horizon_s / self.dt_s))

    def arrived_mask(self, step: int) -> np.ndarray:
        return self.arr <= step * self.dt_s

    def deficit_allowance(self, step: int) -> np.ndarray:
        """Per-flow remaining bytes a *dedicated NIC* would still have at
        `step`: sizes - nic * time-since-start (clipped).  Backlog above
        this floor is a genuine service deficit; backlog below it is
        just bytes no network could have moved yet (e.g. a 1 GB flow
        that arrived moments before the snapshot), which must not count
        against admission."""
        elapsed_s = np.maximum(step - self.start_step, 0) * self.dt_s
        return self.sizes - np.minimum(self.sizes, self.nic_Bps * elapsed_s)


def build_scenario(
    network: str,                 # opera | expander | clos | rotornet
    workload: str,                # datamining | websearch | hadoop
    load: float,
    num_hosts: int = 648,
    link_gbps: float = 10.0,
    horizon_s: float = 2.0,
    dt_s: float = 2e-4,
    base_rtt_us: float = 20.0,
    cycle_ms: float = 10.7,
    seed: int = 0,
    tail_s: float = 0.5,
) -> FlowScenario:
    rng = np.random.default_rng(seed)
    agg_bps = num_hosts * link_gbps * 1e9
    mean_sz = mean_flow_size(workload)
    lam = load * agg_bps / 8.0 / mean_sz  # flows / s

    n = max(int(lam * horizon_s), 1)
    arr = np.sort(rng.uniform(0, horizon_s, n))
    sizes = sample_flow_sizes(workload, n, rng)

    op = C.OPERA_648_PT
    ex = C.EXPANDER_650_PT
    if network == "opera":
        lat_pool = C.latency_capacity(op) * agg_bps / 8.0
        bulk_pool = C.bulk_capacity_opera(op) * agg_bps / 8.0
        is_bulk = sizes >= BULK_CUTOFF
        start_delay = np.where(
            is_bulk, rng.uniform(0, cycle_ms / 1e3, n), base_rtt_us * 1e-6
        )
    elif network == "rotornet":
        # non-hybrid RotorNet: EVERYTHING waits for direct circuits
        lat_pool = 0.0
        bulk_pool = C.bulk_capacity_opera(op) * agg_bps / 8.0
        is_bulk = np.ones(n, bool)
        start_delay = rng.uniform(0, cycle_ms / 1e3, n)
    elif network == "expander":
        lat_pool = C.latency_capacity(ex) * agg_bps / 8.0
        bulk_pool = 0.0
        is_bulk = np.zeros(n, bool)
        start_delay = np.full(n, base_rtt_us * 1e-6)
    elif network == "clos":
        lat_pool = C.clos_capacity(3.0) * agg_bps / 8.0
        bulk_pool = 0.0
        is_bulk = np.zeros(n, bool)
        start_delay = np.full(n, base_rtt_us * 1e-6)
    else:
        raise ValueError(network)

    return FlowScenario(
        network=network,
        workload=workload,
        load=load,
        seed=seed,
        horizon_s=horizon_s,
        dt_s=dt_s,
        tail_s=tail_s,
        num_hosts=num_hosts,
        link_gbps=link_gbps,
        arr=arr,
        sizes=sizes,
        start_step=np.ceil((arr + start_delay) / dt_s).astype(np.int32),
        is_bulk=is_bulk,
        lat_pool_Bps=float(lat_pool),
        bulk_pool_Bps=float(bulk_pool),
    )


def build_mixed_scenario(
    ws_load: float,
    bulk_load: float,
    num_hosts: int = 648,
    link_gbps: float = 10.0,
    horizon_s: float = 1.0,
    dt_s: float = 2e-4,
    base_rtt_us: float = 20.0,
    cycle_ms: float = 10.7,
    bulk_flow_bytes: float = 64e6,
    seed: int = 0,
    tail_s: float = 0.0,
) -> FlowScenario:
    """Fig. 10's mixed offering on Opera pools: Websearch flows at
    `ws_load` on the latency path plus fixed-size (>= cutoff) bulk flows
    offering `bulk_load` of host bandwidth on the direct-circuit path.

    The bulk pool only gets the fabric slots the latency class leaves:
    admitted latency load x consumes x * avg_hops link-slots (the
    wire-byte tax), exactly the accounting of fig10's analytic column —
    so the flow-measured aggregate throughput is an end-to-end
    cross-check of that model."""
    rng = np.random.default_rng(seed)
    agg_Bps = num_hosts * link_gbps * 1e9 / 8.0

    n_ws = max(int(ws_load * agg_Bps / mean_flow_size("websearch") * horizon_s), 0)
    arr_ws = np.sort(rng.uniform(0, horizon_s, n_ws))
    sz_ws = sample_flow_sizes("websearch", n_ws, rng)

    n_bk = max(int(bulk_load * agg_Bps / bulk_flow_bytes * horizon_s), 1)
    arr_bk = np.sort(rng.uniform(0, horizon_s, n_bk))
    sz_bk = np.full(n_bk, bulk_flow_bytes)

    arr = np.concatenate([arr_ws, arr_bk])
    sizes = np.concatenate([sz_ws, sz_bk])
    is_bulk = np.concatenate([np.zeros(n_ws, bool), np.ones(n_bk, bool)])
    delay = np.concatenate(
        [np.full(n_ws, base_rtt_us * 1e-6),
         rng.uniform(0, cycle_ms / 1e3, n_bk)]
    )
    op = C.OPERA_648_PT
    ws_adm = min(ws_load, C.latency_capacity(op))
    slots = op.duty * op.u / op.d
    bulk_frac = max(C.ETA_DIRECT * (slots - ws_adm * op.avg_hops), 0.0)
    return FlowScenario(
        network="opera",
        workload="mixed-ws-bulk",
        load=ws_load + bulk_load,
        seed=seed,
        horizon_s=horizon_s,
        dt_s=dt_s,
        tail_s=tail_s,
        num_hosts=num_hosts,
        link_gbps=link_gbps,
        arr=arr,
        sizes=sizes,
        start_step=np.ceil((arr + delay) / dt_s).astype(np.int32),
        is_bulk=is_bulk,
        lat_pool_Bps=float(C.latency_capacity(op) * agg_Bps),
        bulk_pool_Bps=float(bulk_frac * agg_Bps),
    )


def _oracle_steps(
    scn: FlowScenario, trace: bool = False
) -> Tuple[np.ndarray, np.ndarray, float, float, Optional[np.ndarray]]:
    """The fixed-dt processor-sharing recurrence, numpy float64.

    Returns (done_step, remaining, deficit_mid, deficit_end, trace)
    where done_step[i] is the step index at whose END flow i finished
    (-1 if unfinished) and deficit_mid/deficit_end are the NIC-bound
    service deficits (see `FlowScenario.deficit_allowance`) at the
    half-horizon / horizon snapshots.  `flows_jax._flow_step` implements
    identical per-step math in jnp — change the two together."""
    n = scn.num_flows
    nic = scn.nic_Bps
    faulted = scn.has_faults
    remaining = scn.sizes.astype(np.float64).copy()
    done_step = np.full(n, -1, np.int64)
    allow_mid = scn.deficit_allowance(scn.mid_step)
    allow_end = scn.deficit_allowance(scn.end_step)
    rem_mid = rem_end = 0.0
    last_start = int(scn.start_step.max()) if n else 0
    traces: List[np.ndarray] = []
    for step in range(scn.steps):
        active = (step >= scn.start_step) & (remaining > 0)
        if faulted:
            # frozen: behind a detected-dead ToR — out of the share
            # computation entirely until recovery, then retries.
            # blackholed: committed to a dead circuit pre-detection —
            # still consumes its share, makes zero progress.
            frozen = (step >= scn.frz_start) & (step < scn.frz_end)
            blackhole = (step >= scn.blk_start) & (step < scn.blk_end)
            sharing = active & ~frozen
        else:
            sharing = active
        if step == scn.mid_step:
            rem_mid = float(np.maximum(remaining - allow_mid, 0.0).sum())
        if step == scn.end_step:
            rem_end = float(np.maximum(remaining - allow_end, 0.0).sum())
        if not trace and not active.any() and step > last_start \
                and step > scn.end_step:
            break
        for pool_Bps, scale, mask in (
            (scn.lat_pool_Bps, scn.lat_scale, sharing & ~scn.is_bulk),
            (scn.bulk_pool_Bps, scn.bulk_scale, sharing & scn.is_bulk),
        ):
            if faulted:
                pool_Bps = pool_Bps * float(scale[step])
            k = int(mask.sum())
            if k == 0 or pool_Bps <= 0:
                continue
            share = min(pool_Bps / k, nic) * scn.dt_s
            if faulted:
                prog = mask & ~blackhole
                remaining[prog] -= np.minimum(remaining[prog], share)
            else:
                remaining[mask] -= np.minimum(remaining[mask], share)
            newly = mask & (remaining <= 0) & (done_step < 0)
            done_step[newly] = step + 1
        if trace:
            traces.append(remaining.copy())   # post-step, like the scan's ys
    return done_step, remaining, rem_mid, rem_end, (
        np.asarray(traces) if trace else None
    )


def percentile_fct(fct_ms: np.ndarray, sel: np.ndarray, ok: np.ndarray) -> float:
    """99th-percentile FCT of the selected class, robust to small n.

    - empty class (no flows sampled): 0.0 — a documented sentinel that
      keeps benchmark JSON and `summarize` means finite;
    - unfinished flows present and <5 finished: +inf (overload signal);
    - otherwise: the finite empirical percentile over finished flows,
      however few there are.
    """
    if not sel.any():
        return 0.0
    done = sel & ok
    if done.sum() == 0:
        return float("inf")
    if (sel & ~ok).any() and done.sum() < 5:
        return float("inf")
    return float(np.percentile(fct_ms[done], 99))


def hist_percentile(hist: np.ndarray, q: float) -> float:
    """Quantile of a log-binned FCT histogram, numpy.percentile-
    compatible: the rank is interpolated between the two bracketing
    order statistics exactly as np.percentile's linear rule, but each
    order statistic is represented by its bin's geometric center — so
    the result is within one bin of the exact empirical percentile."""
    hist = np.asarray(hist, np.int64)
    k = int(hist.sum())
    if k == 0:
        return float("nan")
    edges = fct_hist_edges()
    centers = np.sqrt(edges[:-1] * edges[1:])
    cum = np.cumsum(hist)
    p = (k - 1) * (q / 100.0)
    lo_rank = int(np.floor(p)) + 1            # 1-indexed order statistic
    frac = p - np.floor(p)
    v_lo = centers[np.searchsorted(cum, lo_rank)]
    v_hi = centers[np.searchsorted(cum, min(lo_rank + 1, k))]
    return float(v_lo * (v_hi / v_lo) ** frac)


def percentile_fct_streamed(
    hist_class: np.ndarray, n_class: int, done_class: int
) -> float:
    """`percentile_fct`'s sentinel semantics on a streamed histogram:
    0.0 for an empty class, +inf for the overload signals, else the
    histogram-quantile 99th percentile."""
    if n_class == 0:
        return 0.0
    if done_class == 0:
        return float("inf")
    if n_class > done_class and done_class < 5:
        return float("inf")
    return hist_percentile(hist_class, 99.0)


def _stability(scn: FlowScenario, rem_mid: float, rem_end: float) -> float:
    """Deficit-growth fraction over the second half of the arrival
    window.  Stable systems hold the NIC-bound service deficit
    ~stationary; overloaded ones grow it by (1 - capacity/load) of the
    newly offered work.  (Raw backlog would flag heavy-tailed low
    loads: one 1 GB flow arriving just before the snapshot IS backlog,
    but no network could have served it yet.)

    Zero-size pad flows are masked out *before* the sums (not just as
    zero addends): numpy's pairwise summation regroups with array
    length, so padded and unpadded scenarios would otherwise differ in
    the last ulp."""
    sizes = scn.sizes
    real = sizes > 0
    arrived_mid = float(sizes[real & scn.arrived_mask(scn.mid_step)].sum())
    arrived_end = float(sizes[real & scn.arrived_mask(scn.end_step)].sum())
    newly_offered = max(arrived_end - arrived_mid, 1.0)
    return max(rem_end - rem_mid, 0.0) / newly_offered


def finalize(
    scn: FlowScenario,
    done_step: np.ndarray,
    rem_mid: float,
    rem_end: float,
) -> FlowSimResult:
    """Raw completion steps -> FlowSimResult.  Shared verbatim by the
    numpy oracle and the batched JAX engine.  Zero-size flows are
    padding (never servable, never finished) and are excluded from
    every class mask and fraction, so padded and unpadded scenarios
    finalize identically."""
    ok = done_step >= 0
    fct_ms = np.where(ok, done_step * scn.dt_s - scn.arr, np.inf) * 1e3
    sizes = scn.sizes
    real = sizes > 0
    small = real & (sizes < 100e3)
    mid = real & (sizes >= 100e3) & (sizes < BULK_CUTOFF)
    large = sizes >= BULK_CUTOFF
    growth = _stability(scn, rem_mid, rem_end)
    return FlowSimResult(
        load=scn.load,
        fct_p99_ms_small=percentile_fct(fct_ms, small, ok),
        fct_p99_ms_mid=percentile_fct(fct_ms, mid, ok),
        fct_p99_ms_large=percentile_fct(fct_ms, large, ok),
        fct_mean_ms=float(np.mean(fct_ms[ok])) if ok.any() else float("inf"),
        admitted=growth < 0.08,
        finished_frac=float(ok[real].mean()) if real.any() else 1.0,
        backlog_frac=growth,
    )


def finalize_streamed(
    scn: FlowScenario,
    hist: np.ndarray,
    fct_sum_ms: float,
    rem_mid: float,
    rem_end: float,
) -> FlowSimResult:
    """`finalize` from streamed accumulators instead of per-flow
    completion steps: a (NUM_FCT_CLASSES, FCT_HIST_BINS) completion
    histogram and the summed completion time.  Every finished flow
    lands in exactly one (clipped) bin, so per-class finished counts
    are the exact histogram row sums; percentiles are histogram
    quantiles (within one bin of the exact statistic)."""
    hist = np.asarray(hist, np.int64).reshape(NUM_FCT_CLASSES, FCT_HIST_BINS)
    sizes = scn.sizes
    real = sizes > 0
    cls = fct_class_id(sizes)
    n_cls = [int((real & (cls == c)).sum()) for c in range(NUM_FCT_CLASSES)]
    done_cls = hist.sum(axis=1)
    done_total = int(done_cls.sum())
    n_real = int(real.sum())
    growth = _stability(scn, rem_mid, rem_end)
    return FlowSimResult(
        load=scn.load,
        fct_p99_ms_small=percentile_fct_streamed(hist[0], n_cls[0], int(done_cls[0])),
        fct_p99_ms_mid=percentile_fct_streamed(hist[1], n_cls[1], int(done_cls[1])),
        fct_p99_ms_large=percentile_fct_streamed(hist[2], n_cls[2], int(done_cls[2])),
        fct_mean_ms=(
            float(fct_sum_ms) / done_total if done_total else float("inf")
        ),
        admitted=growth < 0.08,
        finished_frac=done_total / n_real if n_real else 1.0,
        backlog_frac=growth,
    )


def simulate(
    network: str,
    workload: str,
    load: float,
    num_hosts: int = 648,
    link_gbps: float = 10.0,
    horizon_s: float = 2.0,
    dt_s: float = 2e-4,
    base_rtt_us: float = 20.0,
    cycle_ms: float = 10.7,
    seed: int = 0,
    tail_s: float = 0.5,
) -> FlowSimResult:
    scn = build_scenario(
        network, workload, load,
        num_hosts=num_hosts, link_gbps=link_gbps, horizon_s=horizon_s,
        dt_s=dt_s, base_rtt_us=base_rtt_us, cycle_ms=cycle_ms, seed=seed,
        tail_s=tail_s,
    )
    done_step, _, rem_mid, rem_end, _ = _oracle_steps(scn)
    return finalize(scn, done_step, rem_mid, rem_end)


# ---------------- saturation knee --------------------------------------


@dataclasses.dataclass
class SaturationResult:
    """Knee of the admission curve.  `beyond_grid` is True when the
    network still admits the configured ceiling — the knee is a lower
    bound, not a measurement (the old coarse grid silently clipped at
    0.45 and made this case indistinguishable from a real knee)."""

    load: float
    beyond_grid: bool
    ladder: List[Dict]

    def __float__(self) -> float:
        return self.load


def saturation_load(
    network: str,
    workload: str,
    ceiling: float = 0.60,
    floor: float = 0.02,
    coarse_points: int = 8,
    refine_points: int = 5,
    seeds: Sequence[int] = (0,),
    use_jax: bool = True,
    engine: str = "auto",
    **kw,
) -> SaturationResult:
    """Admission knee by batched bisection up to a configurable ceiling.

    Two rounds of load ladders (each a single vmapped device call when
    `use_jax` — the whole coarse or fine ladder rides the batch axis,
    through the dense or tiled engine per `engine`): a coarse grid on
    [floor, ceiling], then a fine grid inside the bracket where
    admission flips.  A load is admitted when the majority of seeds
    admit it.
    """
    kw.setdefault("horizon_s", 1.0)

    if use_jax:
        from repro.netsim.flows_jax import saturation_ladder as _jax_ladder

        def saturation_ladder(network, workload, loads, seeds=(0,), **kw2):
            return _jax_ladder(network, workload, loads, seeds=seeds,
                               engine=engine, **kw2)
    else:
        def saturation_ladder(network, workload, loads, seeds=(0,), **kw2):
            rows = []
            for load in loads:
                adm = [
                    simulate(network, workload, load, seed=s, **kw2).admitted
                    for s in seeds
                ]
                rows.append(dict(load=float(load),
                                 admitted_frac=float(np.mean(adm))))
            return rows

    def knee(loads: np.ndarray) -> Tuple[float, Optional[float], List[Dict]]:
        rows = saturation_ladder(network, workload, loads, seeds=seeds, **kw)
        last_ok, first_bad = 0.0, None
        for r in rows:
            if r["admitted_frac"] > 0.5:
                last_ok = r["load"]
            elif first_bad is None:
                first_bad = r["load"]
        return last_ok, first_bad, rows

    coarse = np.linspace(floor, ceiling, coarse_points)
    last_ok, first_bad, ladder = knee(coarse)
    if first_bad is None:
        return SaturationResult(load=ceiling, beyond_grid=True, ladder=ladder)
    if refine_points > 0 and first_bad > last_ok and last_ok > 0.0:
        fine = np.linspace(last_ok, first_bad, refine_points + 2)[1:-1]
        fine_ok, _, fine_rows = knee(fine)
        ladder = sorted(ladder + fine_rows, key=lambda r: r["load"])
        last_ok = max(last_ok, fine_ok)
    return SaturationResult(load=last_ok, beyond_grid=False, ladder=ladder)
