"""Poisson flow-level simulator with processor sharing (Figs. 7, 9, 10).

Flows arrive Poisson at a target load (fraction of aggregate host-link
capacity), draw sizes from a published distribution, and are served by
per-class capacity pools:

  Opera:   <15 MB -> latency pool (immediate, multi-hop, taxed);
           >=15 MB -> bulk pool (direct circuits, tax-free) after a
           uniform wait for the right slice (<= one cycle).
  static:  a single pool (expander: taxed multi-hop; Clos: direct but
           core-capacity-bound).  Priority queuing for short flows is
           modeled by serving the latency class first from the shared pool.

This is the level of abstraction at which the paper's saturation loads
and FCT-vs-load trends are determined; packet/transport micro-behavior
is folded into the calibrated pool capacities (netsim/capacity.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.netsim import capacity as C
from repro.netsim.workloads import mean_flow_size, sample_flow_sizes

BULK_CUTOFF = 15e6


@dataclasses.dataclass
class FlowSimResult:
    load: float
    fct_p99_ms_small: float      # flows < 100 KB
    fct_p99_ms_mid: float        # 100 KB .. 15 MB
    fct_p99_ms_large: float      # >= 15 MB
    fct_mean_ms: float
    admitted: bool               # backlog stable at this load?
    finished_frac: float
    backlog_frac: float = 0.0    # unserved fraction at end of arrivals


def simulate(
    network: str,                 # opera | expander | clos | rotornet
    workload: str,                # datamining | websearch | hadoop
    load: float,
    num_hosts: int = 648,
    link_gbps: float = 10.0,
    horizon_s: float = 2.0,
    dt_s: float = 2e-4,
    base_rtt_us: float = 20.0,
    cycle_ms: float = 10.7,
    seed: int = 0,
) -> FlowSimResult:
    rng = np.random.default_rng(seed)
    agg_bps = num_hosts * link_gbps * 1e9
    mean_sz = mean_flow_size(workload)
    lam = load * agg_bps / 8.0 / mean_sz  # flows / s

    n = max(int(lam * horizon_s), 1)
    arr = np.sort(rng.uniform(0, horizon_s, n))
    sizes = sample_flow_sizes(workload, n, rng)

    op = C.OPERA_648_PT
    ex = C.EXPANDER_650_PT
    if network == "opera":
        lat_pool = C.latency_capacity(op) * agg_bps / 8.0
        bulk_pool = C.bulk_capacity_opera(op) * agg_bps / 8.0
        is_bulk = sizes >= BULK_CUTOFF
        start_delay = np.where(
            is_bulk, rng.uniform(0, cycle_ms / 1e3, n), base_rtt_us * 1e-6
        )
    elif network == "rotornet":
        # non-hybrid RotorNet: EVERYTHING waits for direct circuits
        lat_pool = 0.0
        bulk_pool = C.bulk_capacity_opera(op) * agg_bps / 8.0
        is_bulk = np.ones(n, bool)
        start_delay = rng.uniform(0, cycle_ms / 1e3, n)
    elif network == "expander":
        lat_pool = C.latency_capacity(ex) * agg_bps / 8.0
        bulk_pool = 0.0
        is_bulk = np.zeros(n, bool)
        start_delay = np.full(n, base_rtt_us * 1e-6)
    elif network == "clos":
        lat_pool = C.clos_capacity(3.0) * agg_bps / 8.0
        bulk_pool = 0.0
        is_bulk = np.zeros(n, bool)
        start_delay = np.full(n, base_rtt_us * 1e-6)
    else:
        raise ValueError(network)

    nic_bps = link_gbps * 1e9 / 8.0
    remaining = sizes.copy()
    start = arr + start_delay
    done_t = np.full(n, np.inf)
    t = 0.0
    rem_mid = rem_end = None
    arrived_mid = arrived_end = 0.0
    steps = int(horizon_s / dt_s) + int(0.5 / dt_s)
    for step in range(steps):
        t = step * dt_s
        active = (start <= t) & (remaining > 0)
        if rem_mid is None and t >= horizon_s / 2:
            mask = arr <= t
            rem_mid = float(remaining[mask].sum())
            arrived_mid = float(sizes[mask].sum())
        if rem_end is None and t >= horizon_s:
            mask = arr <= t
            rem_end = float(remaining[mask].sum())
            arrived_end = float(sizes[mask].sum())
        if not active.any():
            if t > arr[-1]:
                break
            continue
        for pool_bps, mask in (
            (lat_pool, active & ~is_bulk),
            (bulk_pool, active & is_bulk),
        ):
            k = int(mask.sum())
            if k == 0 or pool_bps <= 0:
                continue
            share = min(pool_bps / k, nic_bps) * dt_s
            served = np.minimum(remaining[mask], share)
            remaining[mask] -= served
            newly = mask & (remaining <= 0) & np.isinf(done_t)
            done_t[newly] = t + dt_s

    fct = done_t - arr
    ok = np.isfinite(fct)
    finished = float(ok.mean())

    def p99(sel):
        s = sel & ok
        if s.sum() < 5:
            return float("inf") if (sel & ~ok).any() else float("nan")
        return float(np.percentile(fct[s] * 1e3, 99))

    small = sizes < 100e3
    mid = (sizes >= 100e3) & (sizes < BULK_CUTOFF)
    large = sizes >= BULK_CUTOFF
    # stability: did the backlog grow over the second half of the arrival
    # window?  stable systems hold backlog ~constant; overloaded ones grow
    # it by (1 - capacity/load) of the newly offered work.
    if rem_mid is None or rem_end is None:
        growth = 0.0
    else:
        newly_offered = max(arrived_end - arrived_mid, 1.0)
        growth = max(rem_end - rem_mid, 0.0) / newly_offered
    return FlowSimResult(
        load=load,
        fct_p99_ms_small=p99(small),
        fct_p99_ms_mid=p99(mid),
        fct_p99_ms_large=p99(large),
        fct_mean_ms=float(np.mean(fct[ok]) * 1e3) if ok.any() else float("inf"),
        admitted=growth < 0.08,
        finished_frac=finished,
        backlog_frac=growth,
    )


def saturation_load(network: str, workload: str, **kw) -> float:
    """Largest load on a coarse grid that the network still admits."""
    last = 0.0
    for load in (0.01, 0.05, 0.10, 0.15, 0.20, 0.25, 0.30, 0.35, 0.40, 0.45):
        r = simulate(network, workload, load, horizon_s=1.0, **kw)
        if r.admitted:
            last = load
        else:
            break
    return last
