"""Scenario-sweep runner over the batched JAX fluid engine.

Fans a grid of Opera design points (k, num_racks, groups) x workloads x
load levels x demand seeds through `fluid_jax.simulate_rotor_bulk_batch`
— one vmapped, jitted call per design point (shapes differ across
points), the whole scenario grid of a point in a single device program.
This is the whole-grid study loop the bulk figures (8, 10, 12) and the
expander-vs-reconfigurable comparisons in the related work sweep over.

Loads are offered as a fraction of aggregate host NIC bandwidth over one
topology cycle: at load x, every host sources x * link_rate * cycle
bytes, placed by the workload's spatial pattern.  Emitted rows carry the
aggregate stats the fig scripts consume (fct99 / fct_mean / throughput /
bandwidth tax / finished fraction); `summarize` reduces over seeds.

`FlowSweepSpec` / `run_flow_sweep` are the flow-level counterparts: the
(network x workload x load x seed) FCT grids of Figs. 7/9/10 through
`flows_jax.simulate_grid`'s auto/dense/tiled engine dispatch.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.opera_paper import OperaNetConfig
from repro.core.schedule import cycle_timing
from repro.core.topology import build_lifted_opera_topology, build_opera_topology
from repro.netsim.fluid_jax import RotorBatchResult, simulate_rotor_bulk_batch

# Above this rack count `run_design` builds the topology as a lift of a
# small base schedule (exact App-B structure, tractable construction)
# instead of drawing N random perfect matchings directly.
LIFTED_TOPO_RACKS = 128
from repro.netsim.workloads import (
    demand_all_to_all,
    demand_hotrack,
    demand_permutation,
    demand_skew,
)

WORKLOADS = ("shuffle", "permutation", "skew", "hotrack")


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One Opera fabric design: k-radix ToRs split 50/50, u = k/2 rotor
    switches, `groups` switches reconfiguring simultaneously (App. B)."""

    k: int
    num_racks: int
    groups: int = 1
    link_rate_gbps: float = 10.0
    topo_seed: int = 0

    @property
    def name(self) -> str:
        return f"k{self.k}-n{self.num_racks}-g{self.groups}"

    def to_config(self) -> OperaNetConfig:
        return OperaNetConfig(
            name=self.name,
            k=self.k,
            num_racks=self.num_racks,
            hosts_per_rack=self.k // 2,
            num_circuit_switches=self.k // 2,
            link_rate_gbps=self.link_rate_gbps,
            groups=self.groups,
        )


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    designs: Tuple[DesignPoint, ...]
    workloads: Tuple[str, ...] = ("shuffle", "permutation")
    loads: Tuple[float, ...] = (0.1, 0.3)
    seeds: Tuple[int, ...] = (0,)
    skew_frac: float = 0.2          # active-rack fraction for `skew`
    vlb: bool = True
    max_cycles: int = 120
    engine: str = "auto"            # fluid_jax engine: auto | dense | sparse

    @property
    def scenarios_per_design(self) -> int:
        return len(self.workloads) * len(self.loads) * len(self.seeds)


def appendix_b_grid() -> Tuple[DesignPoint, ...]:
    """The full Appendix-B expansion grid: every radix the paper tables
    (k = 8 .. 64), small fabrics at both group counts, and the large
    design points (k >= 32, including the 5184-host k=24-n432 scale
    point's rack count at k=32) that only the sparse engine sweeps —
    dense (S, N, N) matching tensors are hundreds of MB there."""
    return (
        DesignPoint(k=8, num_racks=16, groups=1),
        DesignPoint(k=8, num_racks=16, groups=2),
        DesignPoint(k=12, num_racks=108, groups=1),
        DesignPoint(k=12, num_racks=108, groups=2),
        DesignPoint(k=16, num_racks=128, groups=1),
        DesignPoint(k=24, num_racks=240, groups=2),
        DesignPoint(k=32, num_racks=432, groups=1),
        DesignPoint(k=32, num_racks=512, groups=2),
        DesignPoint(k=64, num_racks=1024, groups=4),
    )


def scenario_demand(
    workload: str,
    cfg: OperaNetConfig,
    load: float,
    seed: int,
    skew_frac: float = 0.2,
) -> np.ndarray:
    """Rack-level demand matrix offering `load` x host NIC x one cycle."""
    cyc_s = cycle_timing(cfg).cycle_ms * 1e-3
    per_host = load * cfg.link_rate_gbps * 1e9 / 8 * cyc_s
    n, d = cfg.num_racks, cfg.hosts_per_rack
    if workload == "shuffle":
        return demand_all_to_all(n, d, per_host / max((n - 1) * d, 1))
    if workload == "permutation":
        return demand_permutation(n, d, per_host, seed=seed)
    if workload == "skew":
        return demand_skew(n, d, per_host, active_frac=skew_frac, seed=seed)
    if workload == "hotrack":
        return demand_hotrack(n, d, per_host)
    raise ValueError(f"unknown workload {workload!r} (one of {WORKLOADS})")


def run_design(
    spec: SweepSpec, dp: DesignPoint
) -> Tuple[List[Dict], RotorBatchResult]:
    """All of one design point's scenarios in a single vmapped call."""
    cfg = dp.to_config()
    if cfg.num_racks > LIFTED_TOPO_RACKS:
        topo = build_lifted_opera_topology(
            cfg.num_racks, cfg.u, seed=dp.topo_seed, groups=cfg.groups
        )
    else:
        topo = build_opera_topology(
            cfg.num_racks, cfg.u, seed=dp.topo_seed, groups=cfg.groups
        )
    grid = list(itertools.product(spec.workloads, spec.loads, spec.seeds))
    demands = np.stack(
        [
            scenario_demand(w, cfg, load, seed, spec.skew_frac)
            for w, load, seed in grid
        ]
    )
    res = simulate_rotor_bulk_batch(
        cfg, demands, vlb=spec.vlb, max_cycles=spec.max_cycles, topo=topo,
        engine=spec.engine,
    )
    t = cycle_timing(cfg)
    host_bw_gbps = cfg.num_hosts * cfg.link_rate_gbps
    rows = []
    for i, (w, load, seed) in enumerate(grid):
        rows.append(
            dict(
                design=dp.name,
                k=dp.k,
                num_racks=dp.num_racks,
                groups=dp.groups,
                workload=w,
                load=load,
                seed=seed,
                fct_99_ms=float(res.fct_99_ms[i]),
                fct_mean_ms=float(res.fct_mean_ms[i]),
                throughput_gbps=float(res.throughput_gbps[i]),
                throughput_frac=float(res.throughput_gbps[i]) / host_bw_gbps,
                bandwidth_tax=float(res.bandwidth_tax[i]),
                finished_frac=float(res.finished_frac[i, -1]),
                slices_run=int(res.slices_run[i]),
                cycle_ms=t.cycle_ms,
                total_bytes=float(res.total_bytes[i]),
            )
        )
    return rows, res


def run_sweep(spec: SweepSpec) -> List[Dict]:
    rows: List[Dict] = []
    for dp in spec.designs:
        r, _ = run_design(spec, dp)
        rows.extend(r)
    return rows


@dataclasses.dataclass(frozen=True)
class FlowSweepSpec:
    """Flow-level analogue of `SweepSpec`: the (network x workload x
    load x seed) grids Figs. 7/9/10 sweep through the batched flow
    engine, with `fluid`-style engine dispatch (`flows_jax`'s
    auto/dense/tiled)."""

    networks: Tuple[str, ...]
    workloads: Tuple[str, ...] = ("websearch",)
    loads: Tuple[float, ...] = (0.05, 0.2)
    seeds: Tuple[int, ...] = (0,)
    engine: str = "auto"            # flows_jax engine: auto | dense | tiled

    @property
    def num_scenarios(self) -> int:
        return (len(self.networks) * len(self.workloads)
                * len(self.loads) * len(self.seeds))


def run_flow_sweep(spec: FlowSweepSpec, **sim_kw) -> List[Dict]:
    """The whole flow grid through one batched device program (dense: a
    single vmapped call; tiled: a shared chunk loop whose every
    dispatch covers the grid).  `sim_kw` goes to
    `flows.build_scenario` (horizon_s, dt_s, num_hosts, ...); rows are
    `summarize`-ready."""
    from repro.netsim.flows_jax import simulate_grid

    return simulate_grid(
        spec.networks, spec.workloads, spec.loads, seeds=spec.seeds,
        engine=spec.engine, **sim_kw,
    )


def summarize(
    rows: Sequence[Dict],
    by: Tuple[str, ...] = ("design", "workload", "load"),
    stats: Tuple[str, ...] = (
        "fct_99_ms", "fct_mean_ms", "throughput_frac", "bandwidth_tax",
        "finished_frac",
    ),
) -> List[Dict]:
    """Mean over everything not in `by` (i.e. over demand seeds)."""
    groups: Dict[Tuple, List[Dict]] = {}
    for r in rows:
        groups.setdefault(tuple(r[k] for k in by), []).append(r)
    out = []
    for key, members in sorted(groups.items()):
        row = dict(zip(by, key), n=len(members))
        for s in stats:
            row[s] = float(np.mean([m[s] for m in members]))
        out.append(row)
    return out
