"""Seeded, time-indexed fault injection for the batched netsim engines.

Opera's robustness story (§3.3/§3.4, Fig. 11, App. E) is *graceful
degradation*: a failed link, ToR, or rotor switch blackholes the traffic
already committed to it until the hello protocol notices (a detection
lag of a few slices), after which direct traffic re-queues for a live
slot and RotorLB's indirect spreading routes around the dead capacity;
recovered components simply rejoin the schedule and frozen traffic
retries.  This module turns that story into data the array engines can
scan:

* a `FailureSchedule` is a seeded, reproducible list of `FailureEvent`s
  — each failing a set of physical components at an onset step, becoming
  *detected* ``detect_lag`` steps later, and (optionally) recovering;
* `compile_fault_masks` lowers a batch of schedules onto the physical
  uplink grid ``(rack, switch)`` — the N*u fibers of the design, with
  switch failures folded in as whole-column outages — producing per-row
  int32 onset/detect/recover arrays plus the design-time `switch_id`
  tensor that maps every edge of ``OperaTopology.matching_tensor()`` to
  the switch serving it.  The engines rebuild the per-step 0/1 masks
  from these arrays inside their scans (pure comparisons on the global
  step counter: no per-draw recompilation, one lowering per design
  point);
* `step_masks` is the shared numpy reference for that per-step mask
  math — the fluid oracle (`fluid.rotor_slice_step_faulted`) consumes
  it directly and `fluid_jax._slice_step_faulted` mirrors it in jnp;
* `apply_flow_faults` projects a schedule onto a `FlowScenario` as
  per-flow blackhole/frozen windows plus per-step pool-capacity scales,
  the shape the flow-level pair (`flows._oracle_steps` /
  `flows_jax._flow_step`) consumes.

Mask semantics (both engine pairs; the lockstep contract):

* **blackhole window** ``[onset, detect)``: the component is dead but
  senders don't know — bytes committed to it consume wire slots and are
  lost in flight, so they stay queued at the source (retransmit) and
  are counted as ``blackholed``;
* **detected window** ``[detect, recover)``: the component is masked
  out of the offered capacity — direct traffic re-queues, VLB spreads
  only over live room, flows behind a failed ToR freeze;
* **recovery** at ``recover_step``: masks lift, frozen traffic retries.

`FailureSchedule.empty()` compiles to all-ones masks and is guaranteed
bit-identical to the failure-free engine paths (verified by
tests/test_netsim_faults.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.topology import OperaTopology

NEVER = np.int32(2**31 - 1)      # onset/recover sentinel: "not in this run"
DEFAULT_DETECT_LAG = 3           # steps (slices) until hello protocol notices

KINDS = ("link", "tor", "switch")


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One failure: a set of same-kind components with a common timeline.

    ``ids`` are ``(rack, switch)`` uplink pairs for kind="link", rack ids
    for kind="tor", switch ids for kind="switch" — always stored sorted
    so iteration order never depends on set hashing.
    """

    kind: str
    ids: Tuple
    onset_step: int
    detect_lag: int = DEFAULT_DETECT_LAG
    recover_step: Optional[int] = None    # None = never recovers

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind {self.kind!r} not in {KINDS}")
        object.__setattr__(self, "ids", tuple(sorted(self.ids)))
        if self.recover_step is not None and self.recover_step <= self.onset_step:
            raise ValueError("recover_step must be > onset_step")

    @property
    def detect_step(self) -> int:
        return self.onset_step + self.detect_lag

    @property
    def recover(self) -> int:
        return int(NEVER) if self.recover_step is None else self.recover_step


@dataclasses.dataclass(frozen=True)
class FailureSchedule:
    """A reproducible, time-indexed failure draw for one design point.

    Step units are the consuming engine's steps (topology slices for the
    fluid pair, dt ticks for the flow pair); the schedule itself is
    unit-agnostic.  ``seed`` records the draw for provenance — two
    `draw()` calls with equal arguments produce equal schedules.
    """

    num_racks: int
    num_switches: int
    events: Tuple[FailureEvent, ...] = ()
    seed: Optional[int] = None

    @classmethod
    def empty(cls, topo: OperaTopology) -> "FailureSchedule":
        """The no-failure schedule: compiles to all-live masks and is
        bit-identical to the failure-free engine paths."""
        return cls(num_racks=topo.num_racks, num_switches=topo.num_switches)

    @property
    def is_empty(self) -> bool:
        """True when the schedule carries no events.  The engines use
        this to dispatch to the original failure-free program, which is
        what makes `empty()` *bit*-identical: the faulted kernels are
        expression-for-expression equivalent under empty masks, but
        XLA's fusion-dependent reduction order still drifts the last
        f32 ulp between two different programs."""
        return not self.events

    @classmethod
    def draw(
        cls,
        topo: OperaTopology,
        seed: int,
        link_frac: float = 0.0,
        tor_frac: float = 0.0,
        switch_count: int = 0,
        onset_step: int = 0,
        detect_lag: int = DEFAULT_DETECT_LAG,
        recover_step: Optional[int] = None,
    ) -> "FailureSchedule":
        """Seeded draw: `link_frac` of the topology's *realized* uplinks
        (never a non-edge — the Fig. 11 sampler contract), `tor_frac` of
        racks, and the `switch_count` lowest-id rotor switches."""
        rng = np.random.default_rng(seed)
        events: List[FailureEvent] = []
        kw = dict(onset_step=onset_step, detect_lag=detect_lag,
                  recover_step=recover_step)
        if link_frac > 0:
            ups = live_uplinks(topo)
            k = max(1, int(round(link_frac * len(ups))))
            sel = rng.choice(len(ups), size=min(k, len(ups)), replace=False)
            events.append(FailureEvent(
                "link", tuple(ups[i] for i in sorted(sel)), **kw))
        if tor_frac > 0:
            k = max(1, int(round(tor_frac * topo.num_racks)))
            tors = rng.choice(topo.num_racks, size=k, replace=False)
            events.append(FailureEvent("tor", tuple(int(t) for t in tors), **kw))
        if switch_count > 0:
            events.append(FailureEvent(
                "switch", tuple(range(min(switch_count, topo.num_switches))),
                **kw))
        return cls(num_racks=topo.num_racks, num_switches=topo.num_switches,
                   events=tuple(events), seed=seed)

    def to_failure_set(self):
        """Steady-state (all events, time ignored) view for the static
        connectivity/stretch cross-checks in `repro.core.routing`."""
        from repro.core.routing import FailureSet

        fs = FailureSet()
        for ev in self.events:
            if ev.kind == "link":
                fs.uplinks.update((int(r), int(s)) for r, s in ev.ids)
            elif ev.kind == "tor":
                fs.tors.update(int(t) for t in ev.ids)
            else:
                fs.switches.update(int(s) for s in ev.ids)
        return fs


def live_uplinks(topo: OperaTopology) -> List[Tuple[int, int]]:
    """The design's realized physical ``(rack, switch)`` uplinks, sorted.

    An uplink exists iff some matching of switch s gives rack r a
    partner (self-loop-only assignments use no fiber).  For the paper's
    k12-n108 point this is the full N*u = 648 grid."""
    idx = np.arange(topo.num_racks)
    ups = set()
    for s in range(topo.num_switches):
        for p in topo.all_matchings_for_switch(s):
            for r in idx[p != idx]:
                ups.add((int(r), int(s)))
    return sorted(ups)


def switch_id_tensor(topo: OperaTopology) -> np.ndarray:
    """(num_slices, N, N) int32: the switch serving each live edge of
    `matching_tensor()`; the virtual always-alive id ``num_switches``
    marks non-edges.  Symmetric because matchings are involutions —
    design-time state, shared by the oracle and the JAX engine."""
    n, S = topo.num_racks, topo.num_switches
    idx = np.arange(n)
    out = np.full((topo.num_slices, n, n), S, np.int32)
    for t in range(topo.num_slices):
        for s, p in topo.live_matchings(t):
            mask = p != idx
            out[t, idx[mask], p[mask]] = s
    return out


@dataclasses.dataclass
class FaultMasks:
    """Compiled, batched fault timelines over the physical uplink grid.

    ``up_*`` are (B, N, S+1) int32 — column S is the virtual always-alive
    switch non-edges map to; ``tor_*`` are (B, N) int32.  A component is
    physically dead on ``[onset, recover)`` and *known* dead on
    ``[detect, recover)``; `NEVER` means "not in this run"."""

    switch_id: np.ndarray   # (num_slices, N, N) int32, shared per design
    pair_switch: np.ndarray  # (N, N) int32: the ONE switch serving a pair
    up_onset: np.ndarray    # (B, N, S+1)
    up_detect: np.ndarray
    up_recover: np.ndarray
    tor_onset: np.ndarray   # (B, N)
    tor_detect: np.ndarray
    tor_recover: np.ndarray

    @property
    def batch_size(self) -> int:
        return self.up_onset.shape[0]

    def broadcast_to(self, B: int) -> "FaultMasks":
        """Tile a batch-of-one mask set across B scenario rows."""
        if self.batch_size == B:
            return self
        if self.batch_size != 1:
            raise ValueError(
                f"cannot broadcast batch {self.batch_size} to {B}")

        def bc(a):
            return np.ascontiguousarray(
                np.broadcast_to(a, (B,) + a.shape[1:]))

        return FaultMasks(
            switch_id=self.switch_id,
            pair_switch=self.pair_switch,
            up_onset=bc(self.up_onset), up_detect=bc(self.up_detect),
            up_recover=bc(self.up_recover),
            tor_onset=bc(self.tor_onset), tor_detect=bc(self.tor_detect),
            tor_recover=bc(self.tor_recover),
        )


def compile_fault_masks(
    topo: OperaTopology,
    schedules: Union[FailureSchedule, Sequence[FailureSchedule]],
) -> FaultMasks:
    """Lower schedule(s) to the batched component-timeline arrays.

    Switch failures become whole uplink columns (every rack's fiber into
    that switch), so the engines need only one mask mechanism.  Events
    are applied in order; a later event on the same component overwrites
    the earlier timeline (deterministic — ids are stored sorted)."""
    if isinstance(schedules, FailureSchedule):
        schedules = [schedules]
    n, S = topo.num_racks, topo.num_switches
    B = len(schedules)
    up_onset = np.full((B, n, S + 1), NEVER, np.int32)
    up_detect = np.full((B, n, S + 1), NEVER, np.int32)
    up_recover = np.full((B, n, S + 1), NEVER, np.int32)
    tor_onset = np.full((B, n), NEVER, np.int32)
    tor_detect = np.full((B, n), NEVER, np.int32)
    tor_recover = np.full((B, n), NEVER, np.int32)
    for b, sched in enumerate(schedules):
        if sched.num_racks != n or sched.num_switches != S:
            raise ValueError(
                f"schedule geometry ({sched.num_racks}, {sched.num_switches})"
                f" != topology ({n}, {S})")
        for ev in sched.events:
            onset, detect, recover = ev.onset_step, ev.detect_step, ev.recover
            if ev.kind == "link":
                for r, s in ev.ids:
                    up_onset[b, r, s] = onset
                    up_detect[b, r, s] = detect
                    up_recover[b, r, s] = recover
            elif ev.kind == "switch":
                for s in ev.ids:
                    up_onset[b, :, s] = onset
                    up_detect[b, :, s] = detect
                    up_recover[b, :, s] = recover
            else:  # tor
                for r in ev.ids:
                    tor_onset[b, r] = onset
                    tor_detect[b, r] = detect
                    tor_recover[b, r] = recover
    switch_id = switch_id_tensor(topo)
    # Every pair's matchings live on exactly ONE switch (Opera's
    # round-robin assignment), so min over slices recovers it; the
    # virtual id S survives only for never-connected pairs.
    return FaultMasks(
        switch_id=switch_id,
        pair_switch=switch_id.min(axis=0),
        up_onset=up_onset, up_detect=up_detect, up_recover=up_recover,
        tor_onset=tor_onset, tor_detect=tor_detect, tor_recover=tor_recover,
    )


def step_masks(
    masks: FaultMasks, b: int, g: int, sl: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Numpy reference for the per-step mask math (batch row b, global
    step g, topology slice sl).  Returns float 0/1 arrays

      ``(e_real, e_known, tor_real, tor_known, pair_dead_known)``

    where an edge is *real*-dead if either endpoint's serving uplink or
    ToR is physically down, and *known*-dead once detected;
    ``pair_dead_known[m, j]`` flags pairs whose *entire* direct
    capacity (all slices — they share one serving switch) is known
    dead, the condition under which RotorLB forwards non-local traffic
    onward instead of waiting for a circuit that will not come.
    `fluid_jax._slice_step_faulted` implements identical math in jnp —
    change the two together."""
    sw = masks.switch_id[sl % masks.switch_id.shape[0]]
    up_f = (g >= masks.up_onset[b]) & (g < masks.up_recover[b])
    up_k = (g >= masks.up_detect[b]) & (g < masks.up_recover[b])
    tor_f = (g >= masks.tor_onset[b]) & (g < masks.tor_recover[b])
    tor_k = (g >= masks.tor_detect[b]) & (g < masks.tor_recover[b])
    i_f = np.take_along_axis(up_f, sw, axis=1)
    i_k = np.take_along_axis(up_k, sw, axis=1)
    e_real = (i_f | i_f.T | tor_f[:, None] | tor_f[None, :]).astype(np.float64)
    e_known = (i_k | i_k.T | tor_k[:, None] | tor_k[None, :]).astype(np.float64)
    p_k = np.take_along_axis(up_k, masks.pair_switch, axis=1)
    pair_dead = (p_k | p_k.T | tor_k[:, None] | tor_k[None, :]).astype(np.float64)
    return (e_real, e_known, tor_f.astype(np.float64),
            tor_k.astype(np.float64), pair_dead)


def masked_tensor(
    topo: OperaTopology,
    schedule: FailureSchedule,
    step: Optional[int] = None,
    tensor: Optional[np.ndarray] = None,
) -> np.ndarray:
    """The post-detection capacity tensor at global step `step` (default:
    every event detected, nothing recovered): the matching tensor with
    known-dead edges and failed ToRs masked out.  This is the artifact
    SC-INV-FAULT verifies (symmetry, subset of the live fabric, and
    connectivity within the declared switch-fault budget)."""
    if step is None:
        step = max((ev.detect_step for ev in schedule.events), default=0)
    masks = compile_fault_masks(topo, schedule)
    ten = (topo.matching_tensor() if tensor is None
           else np.asarray(tensor, np.float32))
    out = np.empty_like(ten)
    for sl in range(ten.shape[0]):
        _, e_known, tor_real, _, _ = step_masks(masks, 0, step, sl)
        out[sl] = (ten[sl] * (1.0 - e_known)
                   * (1.0 - tor_real)[:, None] * (1.0 - tor_real)[None, :])
    return out


# ---------------- flow-level projection -------------------------------------


def apply_flow_faults(scn, schedule: FailureSchedule,
                      assignment_seed: Optional[int] = None):
    """Project a schedule onto a `FlowScenario` (step unit: dt ticks).

    The flow engine has no rack geometry, so the projection assigns each
    flow a seeded (src rack, dst rack) pair plus one uplink choice per
    endpoint, then derives per-flow windows:

    * flows whose path crosses a component during its *blackhole* window
      keep consuming their pool share with zero progress (retransmits
      into the dead circuit) until detection;
    * flows behind a failed ToR are additionally *frozen* from detection
      to recovery — no share, no progress, retry afterwards;
    * detected capacity loss scales both pools by the surviving fabric
      fraction over ``[detect, recover)``.

    Returns a new FlowScenario (dataclasses.replace) with the six fault
    fields populated; an empty schedule returns `scn` unchanged, so the
    engines dispatch it to the original failure-free program and the
    no-op case stays bit-identical."""
    import dataclasses as _dc

    if not schedule.events:
        return scn
    n = scn.num_flows
    steps = scn.steps
    N, S = schedule.num_racks, schedule.num_switches
    seed = (assignment_seed if assignment_seed is not None
            else 1_000_003 * (schedule.seed or 0) + scn.seed + 17)
    rng = np.random.default_rng(seed)
    src = rng.integers(0, N, n)
    dst = (src + 1 + rng.integers(0, max(N - 1, 1), n)) % N
    up_src = rng.integers(0, S, n)   # first-hop uplink draw
    up_dst = rng.integers(0, S, n)   # last-hop downlink draw

    blk_start = np.full(n, NEVER, np.int32)
    blk_end = np.full(n, NEVER, np.int32)
    frz_start = np.full(n, NEVER, np.int32)
    frz_end = np.full(n, NEVER, np.int32)
    lat_scale = np.ones(steps, np.float64)
    bulk_scale = np.ones(steps, np.float64)

    def widen(starts, ends, hit, lo, hi):
        starts[hit] = np.minimum(starts[hit], np.int32(lo))
        ends[hit] = np.where(ends[hit] == NEVER, np.int32(hi),
                             np.maximum(ends[hit], np.int32(hi)))

    n_up = max(N * S, 1)
    for ev in schedule.events:
        onset = ev.onset_step
        detect = min(ev.detect_step, steps)
        recover = min(ev.recover, steps)
        if ev.kind == "tor":
            racks = np.asarray(ev.ids, np.int64)
            hit = np.isin(src, racks) | np.isin(dst, racks)
            cap_frac = len(racks) / max(N, 1)
        elif ev.kind == "switch":
            sws = np.asarray(ev.ids, np.int64)
            hit = np.isin(up_src, sws) | np.isin(up_dst, sws)
            cap_frac = len(sws) / max(S, 1)
        else:  # link: (rack, switch) uplinks
            keys = np.asarray([r * S + s for r, s in ev.ids], np.int64)
            hit = (np.isin(src * S + up_src, keys)
                   | np.isin(dst * S + up_dst, keys))
            cap_frac = len(ev.ids) / n_up
        # blackhole until the hello protocol notices
        widen(blk_start, blk_end, hit, onset, ev.detect_step)
        if ev.kind == "tor":
            # behind a dead ToR: frozen once detected, retry on recovery
            widen(frz_start, frz_end, hit, ev.detect_step, ev.recover)
        # detected capacity loss shrinks both pools until recovery
        if detect < recover:
            lat_scale[detect:recover] *= 1.0 - cap_frac
            bulk_scale[detect:recover] *= 1.0 - cap_frac
    return _dc.replace(
        scn,
        blk_start=blk_start, blk_end=blk_end,
        frz_start=frz_start, frz_end=frz_end,
        lat_scale=lat_scale, bulk_scale=bulk_scale,
    )


def flow_fault_arrays(scn, num_steps: int, order=None, pad_to: int = 0):
    """Staged fault operands for one `FlowScenario`, shared by the
    dense and tiled flow engines: four (n,) int32 per-flow windows and
    two (num_steps,) float32 pool scales.  Fault-free scenarios get
    NEVER-filled windows and unit scales — under the faulted lowering
    those reduce to the plain recurrence.  `order` reindexes the
    windows for the tiled engine's sorted layout; `pad_to` right-pads
    the windows with NEVER for tile alignment."""
    n = scn.num_flows
    P = max(int(pad_to), n)

    def win(w):
        out = np.full(P, NEVER, np.int32)
        if w is not None:
            out[:n] = w if order is None else w[order]
        return out

    lat_scale = np.ones(num_steps, np.float32)
    bulk_scale = np.ones(num_steps, np.float32)
    if scn.has_faults:
        lat_scale[:] = scn.lat_scale[:num_steps]
        bulk_scale[:] = scn.bulk_scale[:num_steps]
    return (win(scn.blk_start), win(scn.blk_end),
            win(scn.frz_start), win(scn.frz_end), lat_scale, bulk_scale)
