"""Published empirical flow-size distributions (Fig. 1) and traffic
patterns (§5.1-5.6).

CDFs are piecewise log-linear encodings of the published curves:
  - Websearch  (DCTCP, Alizadeh et al. [4])
  - Datamining (VL2, Greenberg et al. [21])
  - Hadoop     (Facebook, Roy et al. [39])

The derived statistic that drives Opera's effective bandwidth tax is the
fraction of BYTES in flows below the 15 MB bulk cutoff: ~4 % for
Datamining (§5.1), ~100 % for Websearch (§5.3).
"""
from __future__ import annotations

from bisect import bisect_left
from typing import Dict, List, Tuple

import numpy as np

# (size_bytes, P[size <= s]) — piecewise log-linear between points
WEBSEARCH_CDF: List[Tuple[float, float]] = [
    (6e3, 0.15), (13e3, 0.20), (19e3, 0.30), (33e3, 0.40), (53e3, 0.53),
    (133e3, 0.60), (667e3, 0.70), (1.3e6, 0.80), (3e6, 0.90),
    (6e6, 0.96), (10e6, 0.99), (14e6, 1.00),
]
DATAMINING_CDF: List[Tuple[float, float]] = [
    (100, 0.03), (300, 0.2), (1e3, 0.50), (3e3, 0.68), (10e3, 0.80),
    (100e3, 0.90), (1e6, 0.95), (10e6, 0.973), (100e6, 0.99),
    (250e6, 0.995), (1e9, 1.00),
]
HADOOP_CDF: List[Tuple[float, float]] = [
    (150, 0.1), (1e3, 0.4), (10e3, 0.55), (100e3, 0.70), (300e3, 0.85),
    (1e6, 0.95), (10e6, 0.99), (100e6, 1.00),
]

CDFS: Dict[str, List[Tuple[float, float]]] = {
    "websearch": WEBSEARCH_CDF,
    "datamining": DATAMINING_CDF,
    "hadoop": HADOOP_CDF,
}


def sample_flow_sizes(name: str, n: int, rng: np.random.Generator) -> np.ndarray:
    """Inverse-CDF sampler.  The distribution has an atom of mass p0 at
    the first CDF point (P[S <= s0] = p0, conventionally all at s0) and
    is log-linear between points; u is drawn on the full [0, 1) so the
    atom carries exactly p0 of the samples."""
    cdf = CDFS[name]
    sizes = np.array([s for s, _ in cdf])
    probs = np.array([p for _, p in cdf])
    u = rng.uniform(0.0, 1.0, n)
    idx = np.searchsorted(probs, u)
    idx = np.clip(idx, 1, len(cdf) - 1)
    s0, s1 = sizes[idx - 1], sizes[idx]
    p0, p1 = probs[idx - 1], probs[idx]
    frac = np.clip((u - p0) / np.maximum(p1 - p0, 1e-12), 0.0, 1.0)
    return np.exp(np.log(s0) + frac * (np.log(s1) - np.log(s0)))


def _byte_mass_below(cdf: List[Tuple[float, float]], cutoff: float) -> float:
    """E[S * 1{S < cutoff}] in closed form.

    Between points the CDF is linear in ln s, so the byte mass of a bin
    (s0, s1] is  (p1 - p0) * (s1 - s0) / ln(s1 / s0)  — the integral of
    s dF — truncated at the cutoff; the first point carries an atom of
    p0 * s0 (matching the sampler's convention above)."""
    s_first, p_first = cdf[0]
    total = p_first * s_first if s_first < cutoff else 0.0
    for (s0, p0), (s1, p1) in zip(cdf, cdf[1:]):
        hi = min(cutoff, s1)
        if hi <= s0:
            break
        total += (p1 - p0) * (hi - s0) / np.log(s1 / s0)
    return total


def mean_flow_size(name: str) -> float:
    return float(_byte_mass_below(CDFS[name], np.inf))


def byte_fraction_below(name: str, cutoff: float) -> float:
    """Fraction of bytes carried by flows smaller than `cutoff` — exact
    integral over the piecewise log-linear CDF (no Monte-Carlo)."""
    cdf = CDFS[name]
    return float(_byte_mass_below(cdf, cutoff) / _byte_mass_below(cdf, np.inf))


# ---------------- spatial patterns (§5.2, §5.6) ----------------------------


def demand_all_to_all(num_racks: int, hosts_per_rack: int,
                      flow_bytes: float) -> np.ndarray:
    """Shuffle: every host sends `flow_bytes` to every other host."""
    d = np.full((num_racks, num_racks),
                hosts_per_rack * hosts_per_rack * flow_bytes)
    # intra-rack traffic never enters the fabric
    np.fill_diagonal(d, 0.0)
    return d


def demand_hotrack(num_racks: int, hosts_per_rack: int,
                   bytes_per_host: float) -> np.ndarray:
    d = np.zeros((num_racks, num_racks))
    d[0, 1] = hosts_per_rack * bytes_per_host
    return d


def demand_skew(num_racks: int, hosts_per_rack: int, bytes_per_host: float,
                active_frac: float = 0.2, seed: int = 0) -> np.ndarray:
    """skew[f,1] of [29]: a fraction f of racks are active, uniform among
    the active set."""
    rng = np.random.default_rng(seed)
    k = max(2, int(round(active_frac * num_racks)))
    act = rng.choice(num_racks, k, replace=False)
    d = np.zeros((num_racks, num_racks))
    per = hosts_per_rack * bytes_per_host / (k - 1)
    for i in act:
        for j in act:
            if i != j:
                d[i, j] = per
    return d


def demand_permutation(num_racks: int, hosts_per_rack: int,
                       bytes_per_host: float, seed: int = 0) -> np.ndarray:
    """Host permutation: each host sends to one non-rack-local host."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_racks)
    # Repair self-maps into a derangement.  Two or more fixed points are
    # cycled among themselves (none can become fixed again: the indices
    # are distinct).  A single fixed point i is swapped with its
    # neighbour j — perm[j] == i is impossible (i is already taken by
    # perm[i]), so the swap leaves neither position fixed.
    fixed = np.flatnonzero(perm == np.arange(num_racks))
    if fixed.size > 1:
        perm[fixed] = np.roll(perm[fixed], 1)
    elif fixed.size == 1:
        i = int(fixed[0])
        j = (i + 1) % num_racks
        perm[i], perm[j] = perm[j], perm[i]
    d = np.zeros((num_racks, num_racks))
    d[np.arange(num_racks), perm] = hosts_per_rack * bytes_per_host
    return d
