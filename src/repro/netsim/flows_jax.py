"""Batched JAX flow-level engines (Figs. 7, 9, 10): dense and tiled.

Re-expresses `flows.simulate`'s fixed-dt processor-sharing recurrence as
jitted `lax.scan` programs with `jax.vmap` over a leading scenario axis
— the (network x workload x load x seed) grids the paper's FCT-vs-load
and saturation figures sweep — behind an `engine="auto"|"dense"|"tiled"`
switch mirroring `fluid_jax`'s dense/sparse dispatch:

  dense  — flow state held as (B, n_max) tensors for the whole horizon;
           one compiled call simulates the whole grid.  Exact per-flow
           completion steps; supports `trace=True` (test-sized grids).
  tiled  — flows sorted by activation step and packed into fixed-size
           tiles; each device dispatch scans `chunk_steps` steps over
           only the (B, window, tile) *active window* of tiles (a
           two-pass per-step reduction: per-tile active counts ->
           global pool share -> per-tile service apply).  Tiles leave
           the window when fully drained, so per-step work and peak
           device state track the concurrently-active flow population
           instead of the scenario's whole lifetime — the regime that
           makes millions of mostly-short flows affordable.  FCT
           percentiles stream out of log-binned on-device histograms
           (`flows.finalize_streamed`); per-flow `done_step` never
           round-trips to the host.

The per-step math is numerically identical to the numpy oracle
(`flows._oracle_steps`) and is lockstep-tested by tests/test_flows_jax.py
and tests/test_flows_tiled.py; the dense and tiled engines share
`_hist_accumulate`, so their completion histograms agree bitwise.

Internals: byte quantities are normalized to one NIC-step of service
(`nic_Bps * dt`) so float32 keeps ample mantissa headroom; activation
times are pre-discretized to int32 step indices on the host (shared
with the oracle via `flows.FlowScenario`), so there is no float time
comparison on the device.  The dense engine gathers the half-horizon /
horizon service-deficit snapshots against host-precomputed NIC-bound
allowances (`FlowScenario.deficit_allowance`); the tiled engine
recomputes the same allowance on device (in normalized units a
dedicated NIC serves exactly 1.0 per step), because flows outside the
window contribute zero deficit by construction.  Scenarios with fewer
flows than the batch maximum are padded with never-active flows
(remaining = 0, start step beyond the scan); `flows.finalize` ignores
zero-size flows, so padding never shifts a result.  Tiled chunk
programs are shaped by (batch, window, tile, chunk_steps) only — never
by the scenario's flow count — so one lowering serves every load and
seed of a design point (pinned by staticcheck's
`count_tiled_lowerings`).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.netsim.flows import (
    FCT_BIN_LOG2_WIDTH,
    FCT_HIST_BINS,
    FCT_HIST_LO_LOG2,
    NUM_FCT_CLASSES,
    FlowScenario,
    FlowSimResult,
    build_scenario,
    fct_class_id,
    finalize,
    finalize_streamed,
)

# engine="auto" stays dense below this many flows (largest scenario in
# the batch): the dense scan is a single dispatch with no host-side
# chunk loop, which wins while the whole state fits comfortably.
TILED_AUTO_FLOWS = 65536
# trace=True materializes a (B, steps, n_max) float stack; refuse
# clearly above this many elements instead of OOMing deep in XLA.
TRACE_MAX_ELEMS = 1 << 26
# Tiled-engine geometry defaults: tiles of 1024 flows, a window that
# starts at 16 tiles and grows by powers of two on demand, and 128
# scan steps per device dispatch.
DEFAULT_TILE = 1024
DEFAULT_WINDOW_TILES = 16
DEFAULT_CHUNK_STEPS = 128


def resolve_flow_engine(engine: str, n_max: int, trace: bool = False) -> str:
    """'auto' -> 'dense'|'tiled' by scenario size (trace forces dense)."""
    if engine == "auto":
        return "dense" if (trace or n_max < TILED_AUTO_FLOWS) else "tiled"
    if engine not in ("dense", "tiled"):
        raise ValueError(f"engine must be auto|dense|tiled, got {engine!r}")
    return engine


def dense_state_bytes(num_flows: int, batch: int = 1) -> int:
    """Peak device-resident per-flow state of the dense engine (fault-
    free): f32 remaining/allow_mid/allow_end/arr_ms plus the two carried
    deficit-snapshot vectors, int32 start/class/done_step, bool is_bulk
    — 37 B per flow slot, held for the scenario's whole lifetime."""
    return batch * num_flows * 37


def tiled_state_bytes(window_tiles: int, tile_size: int,
                      batch: int = 1) -> int:
    """Peak device-resident per-flow state of the tiled engine (fault-
    free): f32 rem/rem0/arr_ms, int32 start/class, bool is_bulk — 21 B
    per *window slot*, independent of total flow count."""
    return batch * window_tiles * tile_size * 21


def _hist_accumulate(hist, fct_sum, newly, class_id, step, arr_ms, dt_ms):
    """Scatter newly-finished flows into the flat per-class log-spaced
    FCT histogram (`flows.fct_bin`'s device twin) and accumulate the
    completion-time sum.  Shared by the dense and tiled scan bodies, so
    their histograms agree bitwise."""
    fct_ms = dt_ms * (step + 1) - arr_ms
    safe = jnp.where(newly, fct_ms, 1.0)
    b = jnp.floor((jnp.log2(safe) - FCT_HIST_LO_LOG2)
                  * (1.0 / FCT_BIN_LOG2_WIDTH))
    b = jnp.clip(b, 0, FCT_HIST_BINS - 1).astype(jnp.int32)
    idx = (class_id * FCT_HIST_BINS + b).reshape(-1)
    hist = hist.at[idx].add(newly.reshape(-1).astype(hist.dtype))
    fct_sum = fct_sum + jnp.where(newly, fct_ms, 0.0).sum()
    return hist, fct_sum


def _flow_step(carry, step, scn_ops, trace: bool):
    """One fixed-dt step, pure jnp — the dense scan body.

    Mirrors `flows._oracle_steps` exactly (normalized units: every
    flow's per-step NIC budget is 1.0); change the two together.
    """
    remaining, done_step, rem_mid, rem_end, hist, fct_sum = carry
    (start, is_bulk, lat_u, bulk_u, allow_mid, allow_end, mid_step,
     end_step, class_id, arr_ms, dt_ms) = scn_ops
    active = (step >= start) & (remaining > 0)
    # Deficit snapshots stay per-flow vectors; the host sums them at
    # float64 over real flows only, so appending never-active pad flows
    # is bitwise invisible (no device reduction to regroup).
    rem_mid = jnp.where(
        step == mid_step, jnp.maximum(remaining - allow_mid, 0.0), rem_mid
    )
    rem_end = jnp.where(
        step == end_step, jnp.maximum(remaining - allow_end, 0.0), rem_end
    )
    newly_any = jnp.zeros(remaining.shape, bool)
    for pool_u, mask in (
        (lat_u, active & ~is_bulk),
        (bulk_u, active & is_bulk),
    ):
        m = mask.astype(remaining.dtype)
        k = m.sum()
        share = jnp.minimum(pool_u / jnp.maximum(k, 1.0), 1.0)
        share = jnp.where(pool_u > 0, share, 0.0)
        remaining = remaining - jnp.minimum(remaining, share) * m
        newly = mask & (remaining <= 0) & (done_step < 0)
        done_step = jnp.where(newly, step + 1, done_step)
        newly_any = newly_any | newly
    hist, fct_sum = _hist_accumulate(
        hist, fct_sum, newly_any, class_id, step, arr_ms, dt_ms
    )
    carry = (remaining, done_step, rem_mid, rem_end, hist, fct_sum)
    return carry, (remaining if trace else jnp.zeros((), remaining.dtype))


@functools.partial(jax.jit, static_argnames=("num_steps", "trace"))
def _run_batch(
    remaining0, start_step, is_bulk, lat_u, bulk_u,
    allow_mid, allow_end, mid_step, end_step,
    class_id, arr_ms, dt_ms, num_steps: int, trace: bool,
):
    """vmap(scan): batch -> time steps.  All operands carry a leading
    scenario axis except the shared step count."""

    def one_scenario(rem0, start, bulk_mask, lat, blk, amid, aend,
                     mstep, estep, cls, arr, dtm):
        scn_ops = (start, bulk_mask, lat, blk, amid, aend, mstep, estep,
                   cls, arr, dtm)
        carry0 = (
            rem0,
            jnp.full(rem0.shape, -1, jnp.int32),
            jnp.zeros(rem0.shape, rem0.dtype),
            jnp.zeros(rem0.shape, rem0.dtype),
            jnp.zeros(NUM_FCT_CLASSES * FCT_HIST_BINS, jnp.int32),
            jnp.zeros((), rem0.dtype),
        )
        steps = jnp.arange(num_steps, dtype=jnp.int32)
        (remaining, done_step, rem_mid, rem_end, hist, fct_sum), ys = (
            jax.lax.scan(
                lambda c, s: _flow_step(c, s, scn_ops, trace), carry0, steps
            )
        )
        return remaining, done_step, rem_mid, rem_end, hist, fct_sum, ys

    return jax.vmap(one_scenario)(
        remaining0, start_step, is_bulk, lat_u, bulk_u,
        allow_mid, allow_end, mid_step, end_step, class_id, arr_ms, dt_ms,
    )


def _flow_step_faulted(carry, xs, scn_ops, trace: bool):
    """`_flow_step` under per-flow fault windows and per-step pool
    scales — the faulted dense scan body.

    Mirrors `flows._oracle_steps`'s faulted branch exactly: frozen flows
    (detected-dead ToR) leave the share computation, blackholed flows
    (dead circuit, pre-detection) consume their share with zero
    progress, and each pool is scaled by the step's surviving-capacity
    fraction; change the two together.  Windows are data (int32
    comparisons), so one lowering serves every failure draw.
    """
    remaining, done_step, rem_mid, rem_end, hist, fct_sum = carry
    step, lat_scale_t, bulk_scale_t = xs
    (start, is_bulk, lat_u, bulk_u, allow_mid, allow_end, mid_step,
     end_step, class_id, arr_ms, dt_ms,
     blk_start, blk_end, frz_start, frz_end) = scn_ops
    active = (step >= start) & (remaining > 0)
    frozen = (step >= frz_start) & (step < frz_end)
    blackhole = (step >= blk_start) & (step < blk_end)
    sharing = active & ~frozen
    # per-flow snapshots, host-summed — see `_flow_step`
    rem_mid = jnp.where(
        step == mid_step, jnp.maximum(remaining - allow_mid, 0.0), rem_mid
    )
    rem_end = jnp.where(
        step == end_step, jnp.maximum(remaining - allow_end, 0.0), rem_end
    )
    newly_any = jnp.zeros(remaining.shape, bool)
    for pool_u, scale_t, mask in (
        (lat_u, lat_scale_t, sharing & ~is_bulk),
        (bulk_u, bulk_scale_t, sharing & is_bulk),
    ):
        pool_u = pool_u * scale_t
        m = mask.astype(remaining.dtype)
        k = m.sum()
        share = jnp.minimum(pool_u / jnp.maximum(k, 1.0), 1.0)
        share = jnp.where(pool_u > 0, share, 0.0)
        prog = (mask & ~blackhole).astype(remaining.dtype)
        remaining = remaining - jnp.minimum(remaining, share) * prog
        newly = mask & (remaining <= 0) & (done_step < 0)
        done_step = jnp.where(newly, step + 1, done_step)
        newly_any = newly_any | newly
    hist, fct_sum = _hist_accumulate(
        hist, fct_sum, newly_any, class_id, step, arr_ms, dt_ms
    )
    carry = (remaining, done_step, rem_mid, rem_end, hist, fct_sum)
    return carry, (remaining if trace else jnp.zeros((), remaining.dtype))


@functools.partial(jax.jit, static_argnames=("num_steps", "trace"))
def _run_batch_faulted(
    remaining0, start_step, is_bulk, lat_u, bulk_u,
    allow_mid, allow_end, mid_step, end_step, class_id, arr_ms, dt_ms,
    blk_start, blk_end, frz_start, frz_end, lat_scale, bulk_scale,
    num_steps: int, trace: bool,
):
    """`_run_batch` with per-flow fault windows (B, n) and per-step pool
    scales (B, num_steps) vmapped alongside the flow state."""

    def one_scenario(rem0, start, bulk_mask, lat, blk, amid, aend,
                     mstep, estep, cls, arr, dtm, bs, be, fs, fe, lsc, bsc):
        scn_ops = (start, bulk_mask, lat, blk, amid, aend, mstep, estep,
                   cls, arr, dtm, bs, be, fs, fe)
        carry0 = (
            rem0,
            jnp.full(rem0.shape, -1, jnp.int32),
            jnp.zeros(rem0.shape, rem0.dtype),
            jnp.zeros(rem0.shape, rem0.dtype),
            jnp.zeros(NUM_FCT_CLASSES * FCT_HIST_BINS, jnp.int32),
            jnp.zeros((), rem0.dtype),
        )
        steps = jnp.arange(num_steps, dtype=jnp.int32)
        (remaining, done_step, rem_mid, rem_end, hist, fct_sum), ys = (
            jax.lax.scan(
                lambda c, xs: _flow_step_faulted(c, xs, scn_ops, trace),
                carry0, (steps, lsc, bsc)
            )
        )
        return remaining, done_step, rem_mid, rem_end, hist, fct_sum, ys

    return jax.vmap(one_scenario)(
        remaining0, start_step, is_bulk, lat_u, bulk_u,
        allow_mid, allow_end, mid_step, end_step, class_id, arr_ms, dt_ms,
        blk_start, blk_end, frz_start, frz_end, lat_scale, bulk_scale,
    )


# ---------------- tiled streaming engine -------------------------------


def _tiled_step(carry, step, scn_ops):
    """One fixed-dt step over the (window, tile) active slice — the
    tiled scan body.  Identical per-flow math to `_flow_step` /
    `flows._oracle_steps` (change them together); the two-pass
    reduction (per-tile counts -> global share -> per-tile apply) only
    regroups exact small-integer sums, so shares and therefore
    remaining-byte trajectories and histograms match the dense engine
    bitwise.  `live` gates steps past the scenario horizon in the final
    partial chunk."""
    rem, hist, fct_sum, rem_mid, rem_end = carry
    (rem0, start, is_bulk, class_id, arr_ms, lat_u, bulk_u, dt_ms,
     mid_step, end_step, num_steps) = scn_ops
    live = step < num_steps
    active = live & (step >= start) & (rem > 0)
    # NIC-bound deficit allowance on device (normalized units: a
    # dedicated NIC serves exactly 1.0 per step).  Flows outside the
    # window contribute zero deficit: drained tiles have rem == 0,
    # future tiles rem == rem0 == allow.
    allow = rem0 - jnp.minimum(
        rem0, jnp.maximum(step - start, 0).astype(rem.dtype)
    )
    deficit = jnp.maximum(rem - allow, 0.0).sum()
    rem_mid = jnp.where(live & (step == mid_step), deficit, rem_mid)
    rem_end = jnp.where(live & (step == end_step), deficit, rem_end)
    newly_any = jnp.zeros(rem.shape, bool)
    for pool_u, mask in (
        (lat_u, active & ~is_bulk),
        (bulk_u, active & is_bulk),
    ):
        m = mask.astype(rem.dtype)
        k = m.sum(axis=-1).sum()          # per-tile counts -> global pool
        share = jnp.minimum(pool_u / jnp.maximum(k, 1.0), 1.0)
        share = jnp.where(pool_u > 0, share, 0.0)
        rem = rem - jnp.minimum(rem, share) * m
        newly_any = newly_any | (mask & (rem <= 0))
    hist, fct_sum = _hist_accumulate(
        hist, fct_sum, newly_any, class_id, step, arr_ms, dt_ms
    )
    return (rem, hist, fct_sum, rem_mid, rem_end)


def _tiled_step_faulted(carry, xs, scn_ops):
    """`_tiled_step` under per-flow fault windows and per-step pool
    scales — mirrors `_flow_step_faulted` / the oracle's faulted branch
    exactly; change them together."""
    rem, hist, fct_sum, rem_mid, rem_end = carry
    step, lat_scale_t, bulk_scale_t = xs
    (rem0, start, is_bulk, class_id, arr_ms, lat_u, bulk_u, dt_ms,
     mid_step, end_step, blk_start, blk_end, frz_start, frz_end,
     num_steps) = scn_ops
    live = step < num_steps
    active = live & (step >= start) & (rem > 0)
    frozen = (step >= frz_start) & (step < frz_end)
    blackhole = (step >= blk_start) & (step < blk_end)
    sharing = active & ~frozen
    allow = rem0 - jnp.minimum(
        rem0, jnp.maximum(step - start, 0).astype(rem.dtype)
    )
    deficit = jnp.maximum(rem - allow, 0.0).sum()
    rem_mid = jnp.where(live & (step == mid_step), deficit, rem_mid)
    rem_end = jnp.where(live & (step == end_step), deficit, rem_end)
    newly_any = jnp.zeros(rem.shape, bool)
    for pool_u, scale_t, mask in (
        (lat_u, lat_scale_t, sharing & ~is_bulk),
        (bulk_u, bulk_scale_t, sharing & is_bulk),
    ):
        pool_u = pool_u * scale_t
        m = mask.astype(rem.dtype)
        k = m.sum(axis=-1).sum()
        share = jnp.minimum(pool_u / jnp.maximum(k, 1.0), 1.0)
        share = jnp.where(pool_u > 0, share, 0.0)
        prog = (mask & ~blackhole).astype(rem.dtype)
        rem = rem - jnp.minimum(rem, share) * prog
        newly_any = newly_any | (mask & (rem <= 0))
    hist, fct_sum = _hist_accumulate(
        hist, fct_sum, newly_any, class_id, step, arr_ms, dt_ms
    )
    return (rem, hist, fct_sum, rem_mid, rem_end)


@functools.partial(jax.jit, static_argnames=("num_steps", "chunk_steps"))
def _run_tiled_chunk(
    rem, rem0, start, is_bulk, class_id, arr_ms,
    lat_u, bulk_u, dt_ms, mid_step, end_step,
    hist, fct_sum, rem_mid, rem_end, step0,
    num_steps: int, chunk_steps: int,
):
    """`chunk_steps` scan steps over the (B, W, T) active windows, one
    device dispatch.  Histograms and deficit snapshots stay device-
    resident across chunks; only the window's remaining bytes round-
    trip to the host (for tile retirement).  Shapes depend on the
    window geometry only — never on the scenario's total flow count —
    so one lowering serves every load and seed of a design point."""
    steps = step0 + jnp.arange(chunk_steps, dtype=jnp.int32)

    def one_scenario(rm, r0, st, bm, cls, arr, lat, blk, dtm, mstep, estep,
                     h, fs, rmid, rend):
        scn_ops = (r0, st, bm, cls, arr, lat, blk, dtm, mstep, estep,
                   num_steps)

        def body(c, s):
            return _tiled_step(c, s, scn_ops), None

        carry, _ = jax.lax.scan(body, (rm, h, fs, rmid, rend), steps)
        return carry

    return jax.vmap(one_scenario)(
        rem, rem0, start, is_bulk, class_id, arr_ms,
        lat_u, bulk_u, dt_ms, mid_step, end_step,
        hist, fct_sum, rem_mid, rem_end,
    )


@functools.partial(jax.jit, static_argnames=("num_steps", "chunk_steps"))
def _run_tiled_chunk_faulted(
    rem, rem0, start, is_bulk, class_id, arr_ms,
    lat_u, bulk_u, dt_ms, mid_step, end_step,
    blk_start, blk_end, frz_start, frz_end, lat_scale, bulk_scale,
    hist, fct_sum, rem_mid, rem_end, step0,
    num_steps: int, chunk_steps: int,
):
    """`_run_tiled_chunk` with per-flow fault windows (B, W, T) and this
    chunk's per-step pool scales (B, chunk_steps)."""
    steps = step0 + jnp.arange(chunk_steps, dtype=jnp.int32)

    def one_scenario(rm, r0, st, bm, cls, arr, lat, blk, dtm, mstep, estep,
                     bs, be, fs_, fe, lsc, bsc, h, fsum, rmid, rend):
        scn_ops = (r0, st, bm, cls, arr, lat, blk, dtm, mstep, estep,
                   bs, be, fs_, fe, num_steps)

        def body(c, xs):
            return _tiled_step_faulted(c, xs, scn_ops), None

        carry, _ = jax.lax.scan(body, (rm, h, fsum, rmid, rend),
                                (steps, lsc, bsc))
        return carry

    return jax.vmap(one_scenario)(
        rem, rem0, start, is_bulk, class_id, arr_ms,
        lat_u, bulk_u, dt_ms, mid_step, end_step,
        blk_start, blk_end, frz_start, frz_end, lat_scale, bulk_scale,
        hist, fct_sum, rem_mid, rem_end,
    )


class _TiledState:
    """Host-side per-scenario tiled flow state: flows stably sorted by
    activation step, padded to whole tiles, with a monotone window
    [lo, hi) of not-yet-drained tiles that have (or are about to have)
    arrivals.  Because the sort is by start step, the window is always
    a contiguous tile range — plain numpy slices, no gathers."""

    def __init__(self, scn: FlowScenario, tile: int, num_steps: int,
                 faulted: bool):
        n = scn.num_flows
        self.tile = tile
        self.n = n
        self.unit = scn.nic_Bps * scn.dt_s
        self.order = np.argsort(scn.start_step, kind="stable")
        self.ntiles = max(-(-n // tile), 1)
        P = self.ntiles * tile
        sizes = scn.sizes[self.order]
        rem64 = np.zeros(P, np.float64)     # staticcheck: ok SC-AST-F64 (host staging)
        rem64[:n] = sizes / self.unit
        self.rem = rem64.astype(np.float32)
        self.rem0 = self.rem.copy()
        self.start = np.full(P, num_steps + 1, np.int32)
        self.start[:n] = scn.start_step[self.order]
        self.is_bulk = np.zeros(P, bool)
        self.is_bulk[:n] = scn.is_bulk[self.order]
        self.class_id = np.zeros(P, np.int32)
        self.class_id[:n] = fct_class_id(sizes)
        arr64 = np.zeros(P, np.float64)     # staticcheck: ok SC-AST-F64 (host staging)
        arr64[:n] = scn.arr[self.order] * 1e3
        self.arr_ms = arr64.astype(np.float32)
        # first activation step per tile — non-decreasing (sorted), so
        # the window's upper edge is a searchsorted; pad-only tiles
        # activate "never" and are skipped outright.
        self.tile_first_start = self.start.reshape(self.ntiles, tile)[:, 0].copy()
        self.lo = 0
        if faulted:
            from repro.netsim.faults import flow_fault_arrays

            (self.blk_start, self.blk_end, self.frz_start, self.frz_end,
             self.lat_scale, self.bulk_scale) = flow_fault_arrays(
                scn, num_steps, order=self.order, pad_to=P)

    def window(self, chunk_end: int) -> int:
        """Tiles in [lo, hi) where hi counts tiles with any flow
        activating before `chunk_end`."""
        hi = int(np.searchsorted(self.tile_first_start, chunk_end, "left"))
        return max(hi - self.lo, 0)

    def fill(self, row: Dict[str, np.ndarray], b: int, w: int) -> None:
        t0 = self.lo * self.tile
        sl = slice(t0, t0 + w * self.tile)
        shape = (w, self.tile)
        for name in row:
            row[name][b, :w] = getattr(self, name)[sl].reshape(shape)

    def writeback(self, rem_rows: np.ndarray, w: int) -> None:
        if w:
            t0 = self.lo * self.tile
            self.rem[t0:t0 + w * self.tile] = rem_rows[:w].reshape(-1)

    def advance(self) -> None:
        """Retire the contiguous prefix of fully-drained tiles."""
        while self.lo < self.ntiles:
            sl = slice(self.lo * self.tile, (self.lo + 1) * self.tile)
            if np.all(self.rem[sl] == 0.0):
                self.lo += 1
            else:
                break

    @property
    def done(self) -> bool:
        return self.lo >= self.ntiles

    def remaining_bytes(self) -> np.ndarray:
        """(n,) remaining bytes in the scenario's original flow order."""
        out = np.zeros(self.n, np.float64)  # staticcheck: ok SC-AST-F64 (host staging)
        out[self.order] = self.rem[:self.n]
        return out * self.unit


def _simulate_flows_tiled(
    scenarios: Sequence[FlowScenario],
    dtype,
    tile_size: int,
    window_tiles: int,
    chunk_steps: int,
) -> "FlowBatchResult":
    """The tiled streaming engine's host driver: a chunk loop that
    assembles each scenario's active window into a shared (B, W, T)
    buffer, dispatches one jitted multi-step chunk, writes the surviving
    remaining bytes back, and retires drained tiles.  The window
    capacity W grows by powers of two when any scenario's active window
    outgrows it (monotone, so a design point compiles a handful of
    geometries at most); chunks where every window is empty are skipped
    without a dispatch."""
    num_steps = scenarios[0].steps
    B, T, C = len(scenarios), int(tile_size), int(chunk_steps)
    faulted = any(s.has_faults for s in scenarios)
    states = [_TiledState(s, T, num_steps, faulted) for s in scenarios]

    lat_u = jnp.asarray([s.lat_pool_Bps / s.nic_Bps for s in scenarios], dtype)
    bulk_u = jnp.asarray([s.bulk_pool_Bps / s.nic_Bps for s in scenarios], dtype)
    dt_ms = jnp.asarray([s.dt_s * 1e3 for s in scenarios], dtype)
    mid_step = jnp.asarray([s.mid_step for s in scenarios], jnp.int32)
    end_step = jnp.asarray([s.end_step for s in scenarios], jnp.int32)
    hist = jnp.zeros((B, NUM_FCT_CLASSES * FCT_HIST_BINS), jnp.int32)
    fct_sum = jnp.zeros((B,), dtype)
    rem_mid = jnp.zeros((B,), dtype)
    rem_end = jnp.zeros((B,), dtype)

    window_names = ("rem", "rem0", "start", "is_bulk", "class_id", "arr_ms")
    if faulted:
        window_names += ("blk_start", "blk_end", "frz_start", "frz_end")
        from repro.netsim.faults import NEVER

    W = int(window_tiles)
    peak_w = 0
    c0 = 0
    while c0 < num_steps:
        chunk_end = min(c0 + C, num_steps)
        ws = [st.window(chunk_end) for st in states]
        peak_w = max(peak_w, max(ws))
        if max(ws) == 0:
            if all(st.done for st in states):
                break
            c0 += C
            continue
        while max(ws) > W:
            W *= 2
        row = dict(
            rem=np.zeros((B, W, T), np.float32),
            rem0=np.zeros((B, W, T), np.float32),
            start=np.full((B, W, T), num_steps + 1, np.int32),
            is_bulk=np.zeros((B, W, T), bool),
            class_id=np.zeros((B, W, T), np.int32),
            arr_ms=np.zeros((B, W, T), np.float32),
        )
        if faulted:
            for name in ("blk_start", "blk_end", "frz_start", "frz_end"):
                row[name] = np.full((B, W, T), NEVER, np.int32)
        for b, (st, w) in enumerate(zip(states, ws)):
            st.fill(row, b, w)
        operands = [jnp.asarray(row[name], dtype) if name in
                    ("rem", "rem0", "arr_ms") else jnp.asarray(row[name])
                    for name in window_names]
        if faulted:
            lsc = np.ones((B, C), np.float32)
            bsc = np.ones((B, C), np.float32)
            for b, st in enumerate(states):
                lsc[b, :chunk_end - c0] = st.lat_scale[c0:chunk_end]
                bsc[b, :chunk_end - c0] = st.bulk_scale[c0:chunk_end]
            rem_out, hist, fct_sum, rem_mid, rem_end = _run_tiled_chunk_faulted(
                *operands[:6], lat_u, bulk_u, dt_ms, mid_step, end_step,
                *operands[6:], jnp.asarray(lsc, dtype), jnp.asarray(bsc, dtype),
                hist, fct_sum, rem_mid, rem_end, c0,
                num_steps=num_steps, chunk_steps=C,
            )
        else:
            rem_out, hist, fct_sum, rem_mid, rem_end = _run_tiled_chunk(
                *operands, lat_u, bulk_u, dt_ms, mid_step, end_step,
                hist, fct_sum, rem_mid, rem_end, c0,
                num_steps=num_steps, chunk_steps=C,
            )
        rem_np = np.asarray(rem_out)
        for b, (st, w) in enumerate(zip(states, ws)):
            st.writeback(rem_np[b], w)
            st.advance()
        c0 += C

    units = np.asarray([st.unit for st in states])
    hists = np.asarray(hist, np.int64).reshape(
        B, NUM_FCT_CLASSES, FCT_HIST_BINS
    )
    fct_sums = np.asarray(fct_sum, np.float64)   # staticcheck: ok SC-AST-F64 (host staging)
    rem_mid_B = np.asarray(rem_mid, np.float64) * units  # staticcheck: ok SC-AST-F64 (host staging)
    rem_end_B = np.asarray(rem_end, np.float64) * units  # staticcheck: ok SC-AST-F64 (host staging)
    results = [
        finalize_streamed(s, hists[b], float(fct_sums[b]),
                          rem_mid_B[b], rem_end_B[b])
        for b, s in enumerate(scenarios)
    ]
    remaining_bytes = [st.remaining_bytes() for st in states]
    return FlowBatchResult(
        results, remaining_bytes, traces=None,
        hists=[hists[b] for b in range(B)],
        peak_window_tiles=peak_w,
    )


@dataclasses.dataclass
class FlowBatchResult:
    """Batched engine output: one `FlowSimResult` per scenario (dense:
    `flows.finalize` on exact completion steps; tiled:
    `flows.finalize_streamed` on the device histograms), the per-flow
    remaining bytes at scan end (fig10 integrates these into served
    throughput), each scenario's (classes, bins) completion-time
    histogram, and — dense trace mode, test-sized grids only — each
    scenario's full (steps, n) remaining-bytes trajectory."""

    results: List[FlowSimResult]
    remaining_bytes: List[np.ndarray]       # (n_b,) per scenario
    traces: Optional[List[np.ndarray]] = None
    hists: Optional[List[np.ndarray]] = None
    peak_window_tiles: Optional[int] = None  # tiled engine only


def simulate_flows_batch(
    scenarios: Sequence[FlowScenario],
    dtype=jnp.float32,
    trace: bool = False,
    engine: str = "auto",
    tile_size: int = DEFAULT_TILE,
    window_tiles: int = DEFAULT_WINDOW_TILES,
    chunk_steps: int = DEFAULT_CHUNK_STEPS,
) -> FlowBatchResult:
    """Simulate a batch of flow scenarios on the dense or tiled engine.

    All scenarios must share dt/horizon/tail (one static step count per
    compiled program); flow counts may differ — shorter rows are padded
    with never-active flows.  Rows carrying a fault projection
    (`faults.apply_flow_faults`) route the whole batch through the
    faulted lowering; fault-free batches run the original program
    untouched (bit-identical no-op dispatch).  `engine="auto"` picks
    tiled once the largest scenario reaches `TILED_AUTO_FLOWS` flows
    (trace mode forces dense and is size-gated by `TRACE_MAX_ELEMS`).
    """
    if not scenarios:
        return FlowBatchResult([], [])
    steps = {s.steps for s in scenarios}
    if len(steps) != 1:
        raise ValueError(f"scenarios disagree on step count: {sorted(steps)}")
    num_steps = steps.pop()
    n_max = max(s.num_flows for s in scenarios)
    B = len(scenarios)
    resolved = resolve_flow_engine(engine, n_max, trace)
    if trace:
        if resolved != "dense":
            raise ValueError("trace=True is dense-only: the tiled engine "
                             "never materializes per-flow trajectories")
        elems = B * num_steps * n_max
        if elems > TRACE_MAX_ELEMS:
            raise ValueError(
                f"trace=True would materialize a ({B}, {num_steps}, "
                f"{n_max}) remaining-bytes stack ({elems:,} elements > "
                f"TRACE_MAX_ELEMS={TRACE_MAX_ELEMS:,}); trace mode is for "
                "test-sized grids — drop trace or shrink the scenario"
            )
    if resolved == "tiled":
        return _simulate_flows_tiled(
            scenarios, dtype, tile_size, window_tiles, chunk_steps
        )

    # Host-side staging is float64 on purpose: oracle-shared quantities are
    # normalized at full precision, then cast once at the device boundary.
    remaining0 = np.zeros((B, n_max), np.float64)  # staticcheck: ok SC-AST-F64 (host staging)
    start_step = np.full((B, n_max), num_steps + 1, np.int32)
    is_bulk = np.zeros((B, n_max), bool)
    allow_mid = np.zeros((B, n_max), np.float64)   # staticcheck: ok SC-AST-F64 (host staging)
    allow_end = np.zeros((B, n_max), np.float64)   # staticcheck: ok SC-AST-F64 (host staging)
    class_id = np.zeros((B, n_max), np.int32)
    arr_ms = np.zeros((B, n_max), np.float64)      # staticcheck: ok SC-AST-F64 (host staging)
    lat_u = np.zeros(B)
    bulk_u = np.zeros(B)
    dt_ms = np.zeros(B)
    mid_step = np.zeros(B, np.int32)
    end_step = np.zeros(B, np.int32)
    units = np.zeros(B)
    faulted = any(s.has_faults for s in scenarios)
    if faulted:
        # NEVER-filled windows for fault-free rows and pad flows; unit
        # scales for fault-free rows — the faulted step then reduces to
        # the plain recurrence for them (to f32 fusion tolerance).
        from repro.netsim.faults import NEVER

        blk_start = np.full((B, n_max), NEVER, np.int32)
        blk_end = np.full((B, n_max), NEVER, np.int32)
        frz_start = np.full((B, n_max), NEVER, np.int32)
        frz_end = np.full((B, n_max), NEVER, np.int32)
        lat_scale = np.ones((B, num_steps), np.float64)   # staticcheck: ok SC-AST-F64 (host staging)
        bulk_scale = np.ones((B, num_steps), np.float64)  # staticcheck: ok SC-AST-F64 (host staging)
    for b, s in enumerate(scenarios):
        n = s.num_flows
        unit = s.nic_Bps * s.dt_s          # bytes one NIC serves per step
        units[b] = unit
        remaining0[b, :n] = s.sizes / unit
        start_step[b, :n] = s.start_step
        is_bulk[b, :n] = s.is_bulk
        allow_mid[b, :n] = s.deficit_allowance(s.mid_step) / unit
        allow_end[b, :n] = s.deficit_allowance(s.end_step) / unit
        class_id[b, :n] = fct_class_id(s.sizes)
        arr_ms[b, :n] = s.arr * 1e3
        lat_u[b] = s.lat_pool_Bps / s.nic_Bps
        bulk_u[b] = s.bulk_pool_Bps / s.nic_Bps
        dt_ms[b] = s.dt_s * 1e3
        mid_step[b] = s.mid_step
        end_step[b] = s.end_step
        if faulted and s.has_faults:
            blk_start[b, :n] = s.blk_start
            blk_end[b, :n] = s.blk_end
            frz_start[b, :n] = s.frz_start
            frz_end[b, :n] = s.frz_end
            lat_scale[b] = s.lat_scale[:num_steps]
            bulk_scale[b] = s.bulk_scale[:num_steps]

    common = (
        jnp.asarray(remaining0, dtype),
        jnp.asarray(start_step),
        jnp.asarray(is_bulk),
        jnp.asarray(lat_u, dtype),
        jnp.asarray(bulk_u, dtype),
        jnp.asarray(allow_mid, dtype),
        jnp.asarray(allow_end, dtype),
        jnp.asarray(mid_step),
        jnp.asarray(end_step),
        jnp.asarray(class_id),
        jnp.asarray(arr_ms, dtype),
        jnp.asarray(dt_ms, dtype),
    )
    if faulted:
        remaining, done_step, rem_mid, rem_end, hist, _, ys = (
            _run_batch_faulted(
                *common,
                jnp.asarray(blk_start), jnp.asarray(blk_end),
                jnp.asarray(frz_start), jnp.asarray(frz_end),
                jnp.asarray(lat_scale, dtype), jnp.asarray(bulk_scale, dtype),
                num_steps, bool(trace),
            )
        )
    else:
        remaining, done_step, rem_mid, rem_end, hist, _, ys = _run_batch(
            *common, num_steps, bool(trace),
        )
    done_step = np.asarray(done_step)
    # Device f32 results are de-normalized on the host at float64, matching
    # the float64 oracle's finalize() inputs.  The deficit snapshots come
    # back as per-flow vectors and are summed here over *real* flows only:
    # the summed arrays are then identical whether or not never-active pad
    # flows were appended, so padding is bitwise invisible.
    remaining = np.asarray(remaining, np.float64)  # staticcheck: ok SC-AST-F64 (host staging)
    rem_mid = np.asarray(rem_mid, np.float64)  # staticcheck: ok SC-AST-F64 (host staging)
    rem_end = np.asarray(rem_end, np.float64)  # staticcheck: ok SC-AST-F64 (host staging)
    hist = np.asarray(hist, np.int64).reshape(B, NUM_FCT_CLASSES, FCT_HIST_BINS)

    def _deficit(vec, b, s):
        real = s.sizes > 0
        return float(vec[b, : s.num_flows][real].sum()) * units[b]

    results = [
        finalize(s, done_step[b, : s.num_flows],
                 _deficit(rem_mid, b, s), _deficit(rem_end, b, s))
        for b, s in enumerate(scenarios)
    ]
    remaining_bytes = [
        remaining[b, : s.num_flows] * units[b]
        for b, s in enumerate(scenarios)
    ]
    traces = None
    if trace:
        # staticcheck: ok SC-AST-F64 (host staging)
        ys = np.asarray(ys, np.float64)    # (B, steps, n_max)
        traces = [
            ys[b, :, : s.num_flows] * units[b]
            for b, s in enumerate(scenarios)
        ]
    return FlowBatchResult(results, remaining_bytes, traces,
                           hists=[hist[b] for b in range(B)])


def simulate_grid(
    networks: Sequence[str],
    workloads: Sequence[str],
    loads: Sequence[float],
    seeds: Sequence[int] = (0,),
    engine: str = "auto",
    tile_size: int = DEFAULT_TILE,
    window_tiles: int = DEFAULT_WINDOW_TILES,
    chunk_steps: int = DEFAULT_CHUNK_STEPS,
    **kw,
) -> List[Dict]:
    """The full (network x workload x load x seed) grid in ONE batched
    device program (a single vmapped call on the dense engine; a shared
    chunk loop whose every dispatch covers the whole grid on the tiled
    engine).  Returns one flat row per scenario: the grid coordinates
    plus every `FlowSimResult` field — ready for `sweep.summarize`."""
    grid = list(itertools.product(networks, workloads, loads, seeds))
    scenarios = [
        build_scenario(net, w, load, seed=seed, **kw)
        for net, w, load, seed in grid
    ]
    batch = simulate_flows_batch(
        scenarios, engine=engine, tile_size=tile_size,
        window_tiles=window_tiles, chunk_steps=chunk_steps,
    )
    rows = []
    for (net, w, load, seed), r in zip(grid, batch.results):
        row = dict(network=net, workload=w, load=float(load), seed=int(seed))
        row.update(
            (f.name, getattr(r, f.name))
            for f in r.__dataclass_fields__.values()
        )
        rows.append(row)
    return rows


def saturation_ladder(
    network: str,
    workload: str,
    loads: Sequence[float],
    seeds: Sequence[int] = (0,),
    engine: str = "auto",
    **kw,
) -> List[Dict]:
    """A full load ladder (loads x seeds) to the admission knee in one
    batched device program; one row per load with the seed-majority
    admission verdict.  `flows.saturation_load` stacks two of these
    into a batched bisection.  Rows are grouped positionally by grid
    index (the grid is loads-major over seeds), so repeated or
    float-unstable load values can never merge or drop rows."""
    rows = simulate_grid([network], [workload], loads, seeds=seeds,
                         engine=engine, **kw)
    n_seeds = len(seeds)
    if len(rows) != len(loads) * n_seeds:
        raise RuntimeError(
            f"ladder grid returned {len(rows)} rows for "
            f"{len(loads)} loads x {n_seeds} seeds"
        )
    out = []
    for i, load in enumerate(loads):
        mine = rows[i * n_seeds:(i + 1) * n_seeds]
        out.append(
            dict(
                load=float(load),
                admitted_frac=float(np.mean([r["admitted"] for r in mine])),
                backlog_frac=float(np.mean([r["backlog_frac"] for r in mine])),
                finished_frac=float(np.mean([r["finished_frac"] for r in mine])),
            )
        )
    return out
