"""Batched JAX flow-level engine (Figs. 7, 9, 10).

Re-expresses `flows.simulate`'s fixed-dt processor-sharing recurrence as
a jitted `lax.scan` over time steps, with flow state held as dense
tensors — remaining bytes, completion step, class mask, activation step
— and `jax.vmap` over a leading scenario axis: the
(network x workload x load x seed) grids the paper's FCT-vs-load and
saturation figures sweep.  One compiled call simulates the whole grid;
the per-step math is numerically identical to the numpy oracle
(`flows._oracle_steps`) and the two are lockstep-tested by
tests/test_flows_jax.py.  Mirrors the `fluid_jax.py` design for the
bulk side.

Internals: byte quantities are normalized to one NIC-step of service
(`nic_Bps * dt`) so float32 keeps ample mantissa headroom; activation
times are pre-discretized to int32 step indices on the host (shared
with the oracle via `flows.FlowScenario`), so there is no float time
comparison on the device; the half-horizon/horizon service-deficit snapshots
the stability classifier needs are gathered inside the scan at
host-computed step indices against host-precomputed per-flow NIC-bound
allowances (`FlowScenario.deficit_allowance`).  Scenarios with fewer flows than the batch
maximum are padded with never-active flows (remaining = 0, start step
beyond the scan).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.netsim.flows import (
    FlowScenario,
    FlowSimResult,
    build_scenario,
    finalize,
)


def _flow_step(carry, step, scn_ops, trace: bool):
    """One fixed-dt step, pure jnp — the scan body.

    Mirrors `flows._oracle_steps` exactly (normalized units: every
    flow's per-step NIC budget is 1.0); change the two together.
    """
    remaining, done_step, rem_mid, rem_end = carry
    start, is_bulk, lat_u, bulk_u, allow_mid, allow_end, mid_step, end_step = scn_ops
    active = (step >= start) & (remaining > 0)
    rem_mid = jnp.where(
        step == mid_step, jnp.maximum(remaining - allow_mid, 0.0).sum(), rem_mid
    )
    rem_end = jnp.where(
        step == end_step, jnp.maximum(remaining - allow_end, 0.0).sum(), rem_end
    )
    for pool_u, mask in (
        (lat_u, active & ~is_bulk),
        (bulk_u, active & is_bulk),
    ):
        m = mask.astype(remaining.dtype)
        k = m.sum()
        share = jnp.minimum(pool_u / jnp.maximum(k, 1.0), 1.0)
        share = jnp.where(pool_u > 0, share, 0.0)
        remaining = remaining - jnp.minimum(remaining, share) * m
        newly = mask & (remaining <= 0) & (done_step < 0)
        done_step = jnp.where(newly, step + 1, done_step)
    carry = (remaining, done_step, rem_mid, rem_end)
    return carry, (remaining if trace else jnp.zeros((), remaining.dtype))


@functools.partial(jax.jit, static_argnames=("num_steps", "trace"))
def _run_batch(
    remaining0, start_step, is_bulk, lat_u, bulk_u,
    allow_mid, allow_end, mid_step, end_step, num_steps: int, trace: bool,
):
    """vmap(scan): batch -> time steps.  All operands carry a leading
    scenario axis except the shared step count."""

    def one_scenario(rem0, start, bulk_mask, lat, blk, amid, aend, mstep, estep):
        scn_ops = (start, bulk_mask, lat, blk, amid, aend, mstep, estep)
        carry0 = (
            rem0,
            jnp.full(rem0.shape, -1, jnp.int32),
            jnp.zeros((), rem0.dtype),
            jnp.zeros((), rem0.dtype),
        )
        steps = jnp.arange(num_steps, dtype=jnp.int32)
        (remaining, done_step, rem_mid, rem_end), ys = jax.lax.scan(
            lambda c, s: _flow_step(c, s, scn_ops, trace), carry0, steps
        )
        return remaining, done_step, rem_mid, rem_end, ys

    return jax.vmap(one_scenario)(
        remaining0, start_step, is_bulk, lat_u, bulk_u,
        allow_mid, allow_end, mid_step, end_step,
    )


def _flow_step_faulted(carry, xs, scn_ops, trace: bool):
    """`_flow_step` under per-flow fault windows and per-step pool
    scales — the faulted scan body.

    Mirrors `flows._oracle_steps`'s faulted branch exactly: frozen flows
    (detected-dead ToR) leave the share computation, blackholed flows
    (dead circuit, pre-detection) consume their share with zero
    progress, and each pool is scaled by the step's surviving-capacity
    fraction; change the two together.  Windows are data (int32
    comparisons), so one lowering serves every failure draw.
    """
    remaining, done_step, rem_mid, rem_end = carry
    step, lat_scale_t, bulk_scale_t = xs
    (start, is_bulk, lat_u, bulk_u, allow_mid, allow_end, mid_step,
     end_step, blk_start, blk_end, frz_start, frz_end) = scn_ops
    active = (step >= start) & (remaining > 0)
    frozen = (step >= frz_start) & (step < frz_end)
    blackhole = (step >= blk_start) & (step < blk_end)
    sharing = active & ~frozen
    rem_mid = jnp.where(
        step == mid_step, jnp.maximum(remaining - allow_mid, 0.0).sum(), rem_mid
    )
    rem_end = jnp.where(
        step == end_step, jnp.maximum(remaining - allow_end, 0.0).sum(), rem_end
    )
    for pool_u, scale_t, mask in (
        (lat_u, lat_scale_t, sharing & ~is_bulk),
        (bulk_u, bulk_scale_t, sharing & is_bulk),
    ):
        pool_u = pool_u * scale_t
        m = mask.astype(remaining.dtype)
        k = m.sum()
        share = jnp.minimum(pool_u / jnp.maximum(k, 1.0), 1.0)
        share = jnp.where(pool_u > 0, share, 0.0)
        prog = (mask & ~blackhole).astype(remaining.dtype)
        remaining = remaining - jnp.minimum(remaining, share) * prog
        newly = mask & (remaining <= 0) & (done_step < 0)
        done_step = jnp.where(newly, step + 1, done_step)
    carry = (remaining, done_step, rem_mid, rem_end)
    return carry, (remaining if trace else jnp.zeros((), remaining.dtype))


@functools.partial(jax.jit, static_argnames=("num_steps", "trace"))
def _run_batch_faulted(
    remaining0, start_step, is_bulk, lat_u, bulk_u,
    allow_mid, allow_end, mid_step, end_step,
    blk_start, blk_end, frz_start, frz_end, lat_scale, bulk_scale,
    num_steps: int, trace: bool,
):
    """`_run_batch` with per-flow fault windows (B, n) and per-step pool
    scales (B, num_steps) vmapped alongside the flow state."""

    def one_scenario(rem0, start, bulk_mask, lat, blk, amid, aend,
                     mstep, estep, bs, be, fs, fe, lsc, bsc):
        scn_ops = (start, bulk_mask, lat, blk, amid, aend, mstep, estep,
                   bs, be, fs, fe)
        carry0 = (
            rem0,
            jnp.full(rem0.shape, -1, jnp.int32),
            jnp.zeros((), rem0.dtype),
            jnp.zeros((), rem0.dtype),
        )
        steps = jnp.arange(num_steps, dtype=jnp.int32)
        (remaining, done_step, rem_mid, rem_end), ys = jax.lax.scan(
            lambda c, xs: _flow_step_faulted(c, xs, scn_ops, trace),
            carry0, (steps, lsc, bsc)
        )
        return remaining, done_step, rem_mid, rem_end, ys

    return jax.vmap(one_scenario)(
        remaining0, start_step, is_bulk, lat_u, bulk_u,
        allow_mid, allow_end, mid_step, end_step,
        blk_start, blk_end, frz_start, frz_end, lat_scale, bulk_scale,
    )


@dataclasses.dataclass
class FlowBatchResult:
    """Batched engine output: one `FlowSimResult` per scenario (computed
    by the same `flows.finalize` the oracle uses), the per-flow
    remaining bytes at scan end (fig10 integrates these into served
    throughput), and — in trace mode, test-sized grids only — each
    scenario's full (steps, n) remaining-bytes trajectory."""

    results: List[FlowSimResult]
    remaining_bytes: List[np.ndarray]       # (n_b,) per scenario
    traces: Optional[List[np.ndarray]] = None


def simulate_flows_batch(
    scenarios: Sequence[FlowScenario],
    dtype=jnp.float32,
    trace: bool = False,
) -> FlowBatchResult:
    """Simulate a batch of flow scenarios in one vmapped call.

    All scenarios must share dt/horizon/tail (one static step count per
    compiled program); flow counts may differ — shorter rows are padded
    with never-active flows.  Rows carrying a fault projection
    (`faults.apply_flow_faults`) route the whole batch through the
    faulted lowering; fault-free batches run the original program
    untouched (bit-identical no-op dispatch).
    """
    if not scenarios:
        return FlowBatchResult([], [])
    steps = {s.steps for s in scenarios}
    if len(steps) != 1:
        raise ValueError(f"scenarios disagree on step count: {sorted(steps)}")
    num_steps = steps.pop()
    n_max = max(s.num_flows for s in scenarios)
    B = len(scenarios)

    # Host-side staging is float64 on purpose: oracle-shared quantities are
    # normalized at full precision, then cast once at the device boundary.
    remaining0 = np.zeros((B, n_max), np.float64)  # staticcheck: ok SC-AST-F64 (host staging)
    start_step = np.full((B, n_max), num_steps + 1, np.int32)
    is_bulk = np.zeros((B, n_max), bool)
    allow_mid = np.zeros((B, n_max), np.float64)   # staticcheck: ok SC-AST-F64 (host staging)
    allow_end = np.zeros((B, n_max), np.float64)   # staticcheck: ok SC-AST-F64 (host staging)
    lat_u = np.zeros(B)
    bulk_u = np.zeros(B)
    mid_step = np.zeros(B, np.int32)
    end_step = np.zeros(B, np.int32)
    units = np.zeros(B)
    faulted = any(s.has_faults for s in scenarios)
    if faulted:
        # NEVER-filled windows for fault-free rows and pad flows; unit
        # scales for fault-free rows — the faulted step then reduces to
        # the plain recurrence for them (to f32 fusion tolerance).
        from repro.netsim.faults import NEVER

        blk_start = np.full((B, n_max), NEVER, np.int32)
        blk_end = np.full((B, n_max), NEVER, np.int32)
        frz_start = np.full((B, n_max), NEVER, np.int32)
        frz_end = np.full((B, n_max), NEVER, np.int32)
        lat_scale = np.ones((B, num_steps), np.float64)   # staticcheck: ok SC-AST-F64 (host staging)
        bulk_scale = np.ones((B, num_steps), np.float64)  # staticcheck: ok SC-AST-F64 (host staging)
    for b, s in enumerate(scenarios):
        n = s.num_flows
        unit = s.nic_Bps * s.dt_s          # bytes one NIC serves per step
        units[b] = unit
        remaining0[b, :n] = s.sizes / unit
        start_step[b, :n] = s.start_step
        is_bulk[b, :n] = s.is_bulk
        allow_mid[b, :n] = s.deficit_allowance(s.mid_step) / unit
        allow_end[b, :n] = s.deficit_allowance(s.end_step) / unit
        lat_u[b] = s.lat_pool_Bps / s.nic_Bps
        bulk_u[b] = s.bulk_pool_Bps / s.nic_Bps
        mid_step[b] = s.mid_step
        end_step[b] = s.end_step
        if faulted and s.has_faults:
            blk_start[b, :n] = s.blk_start
            blk_end[b, :n] = s.blk_end
            frz_start[b, :n] = s.frz_start
            frz_end[b, :n] = s.frz_end
            lat_scale[b] = s.lat_scale[:num_steps]
            bulk_scale[b] = s.bulk_scale[:num_steps]

    common = (
        jnp.asarray(remaining0, dtype),
        jnp.asarray(start_step),
        jnp.asarray(is_bulk),
        jnp.asarray(lat_u, dtype),
        jnp.asarray(bulk_u, dtype),
        jnp.asarray(allow_mid, dtype),
        jnp.asarray(allow_end, dtype),
        jnp.asarray(mid_step),
        jnp.asarray(end_step),
    )
    if faulted:
        remaining, done_step, rem_mid, rem_end, ys = _run_batch_faulted(
            *common,
            jnp.asarray(blk_start), jnp.asarray(blk_end),
            jnp.asarray(frz_start), jnp.asarray(frz_end),
            jnp.asarray(lat_scale, dtype), jnp.asarray(bulk_scale, dtype),
            num_steps, bool(trace),
        )
    else:
        remaining, done_step, rem_mid, rem_end, ys = _run_batch(
            *common, num_steps, bool(trace),
        )
    done_step = np.asarray(done_step)
    # Device f32 results are de-normalized on the host at float64, matching
    # the float64 oracle's finalize() inputs.
    remaining = np.asarray(remaining, np.float64)  # staticcheck: ok SC-AST-F64 (host staging)
    rem_mid = np.asarray(rem_mid, np.float64) * units  # staticcheck: ok SC-AST-F64 (host staging)
    rem_end = np.asarray(rem_end, np.float64) * units  # staticcheck: ok SC-AST-F64 (host staging)

    results = [
        finalize(s, done_step[b, : s.num_flows], rem_mid[b], rem_end[b])
        for b, s in enumerate(scenarios)
    ]
    remaining_bytes = [
        remaining[b, : s.num_flows] * units[b]
        for b, s in enumerate(scenarios)
    ]
    traces = None
    if trace:
        # staticcheck: ok SC-AST-F64 (host staging)
        ys = np.asarray(ys, np.float64)    # (B, steps, n_max)
        traces = [
            ys[b, :, : s.num_flows] * units[b]
            for b, s in enumerate(scenarios)
        ]
    return FlowBatchResult(results, remaining_bytes, traces)


def simulate_grid(
    networks: Sequence[str],
    workloads: Sequence[str],
    loads: Sequence[float],
    seeds: Sequence[int] = (0,),
    **kw,
) -> List[Dict]:
    """The full (network x workload x load x seed) grid in ONE vmapped
    device call.  Returns one flat row per scenario: the grid coordinates
    plus every `FlowSimResult` field — ready for `sweep.summarize`."""
    grid = list(itertools.product(networks, workloads, loads, seeds))
    scenarios = [
        build_scenario(net, w, load, seed=seed, **kw)
        for net, w, load, seed in grid
    ]
    batch = simulate_flows_batch(scenarios)
    rows = []
    for (net, w, load, seed), r in zip(grid, batch.results):
        row = dict(network=net, workload=w, load=float(load), seed=int(seed))
        row.update(
            (f.name, getattr(r, f.name))
            for f in r.__dataclass_fields__.values()
        )
        rows.append(row)
    return rows


def saturation_ladder(
    network: str,
    workload: str,
    loads: Sequence[float],
    seeds: Sequence[int] = (0,),
    **kw,
) -> List[Dict]:
    """A full load ladder (loads x seeds) to the admission knee in one
    device call; one row per load with the seed-majority admission
    verdict.  `flows.saturation_load` stacks two of these into a
    batched bisection."""
    rows = simulate_grid([network], [workload], loads, seeds=seeds, **kw)
    out = []
    for load in loads:
        mine = [r for r in rows if r["load"] == float(load)]
        out.append(
            dict(
                load=float(load),
                admitted_frac=float(np.mean([r["admitted"] for r in mine])),
                backlog_frac=float(np.mean([r["backlog_frac"] for r in mine])),
                finished_frac=float(np.mean([r["finished_frac"] for r in mine])),
            )
        )
    return out
