"""Batched JAX fluid engine for rotor fabrics.

Re-expresses `fluid.simulate_rotor_bulk` as a jitted `lax.scan` over the
dense ``(num_slices, N, N)`` matching tensor exported at design time by
`OperaTopology.matching_tensor`, with `jax.vmap` over a leading batch
axis of scenarios — the (seed x load-level x workload) grids the paper's
bulk figures sweep.  One compiled call simulates the whole batch; the
per-slice recurrence is numerically identical to the numpy oracle
(`fluid.rotor_slice_step`) and the two are lockstep-tested by
tests/test_netsim_jax.py.

Internals: all byte quantities are normalized to units of one
slice-link capacity (`core.schedule.slice_capacity_bytes`) so float32
keeps ample mantissa headroom, and the topology tensor is a scan
operand — no topology math, python branching, or host sync inside the
step.  The scan runs a fixed ``max_cycles`` budget (scenarios that
finish early just stop moving bytes); completion times are recovered
from the cumulative-delivery trajectory on the host afterwards, exactly
as the oracle's early-exit loop records them.

Two engines share the public API (`engine=` on
`simulate_rotor_bulk_batch`):

* **dense** — the original vmap(scan(scan)) over ``(S, N, N)`` masks.
* **sparse** — gathers over the permutation-sparse
  ``(S, N, u)`` index tensor (`OperaTopology.matching_index_tensor()`,
  sentinel N = dark slot) via the `kernels/rotor_slice` Pallas op,
  cutting the per-slice work from O(N²·u) (the VLB relay matmul) to
  O(N·(N + u)) and the topology artifact from O(S·N²) to O(S·N·u) —
  what makes the k >= 32 Appendix-B points fit on one host.  The
  sparse engine is a *host-side* per-step driver: one jitted call per
  slice, because XLA CPU executes a multi-step program (scan or
  unrolled) several-fold slower per step than the identical step
  compiled alone — measured on the benchmark backend, see
  benchmarks/perf_track.py for the tracked numbers.  ``engine="auto"``
  picks sparse at N >= `SPARSE_AUTO_RACKS`, dense below.  Both engines
  agree with the oracle at f32 ulp tolerance (tests/test_rotor_slice.py
  pins sparse-vs-dense on every default Appendix-B point, faulted and
  unfaulted).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.opera_paper import OperaNetConfig
from repro.core.schedule import cycle_timing, slice_capacity_bytes
from repro.core.topology import OperaTopology, build_opera_topology
from repro.netsim.fluid import RotorFluidResult


def _slice_step(state, adj, vlb: bool):
    """One topology slice, pure jnp — the scan body.

    Mirrors `fluid.rotor_slice_step` exactly (normalized units: every
    live edge's capacity is 1.0); change the two together.
    """
    own, relay, done, wire = state
    send_own = jnp.minimum(own, adj)
    own = own - send_own
    room = adj - send_own
    send_relay = jnp.minimum(relay, room)
    relay = relay - send_relay
    room = room - send_relay
    delivered = send_own.sum() + send_relay.sum()
    done = done + delivered
    wire = wire + delivered
    if vlb:
        elig = jnp.where(adj > 0, 0.0, own)
        q = elig.sum(1)
        r = room.sum(1)
        t = jnp.minimum(q, r)
        take = elig * jnp.where(q > 0, t / jnp.maximum(q, 1e-30), 0.0)[:, None]
        share = room * jnp.where(r > 0, 1.0 / jnp.maximum(r, 1e-30), 0.0)[:, None]
        own = own - take
        relay = relay + share.T @ take
        wire = wire + t.sum()
    return (own, relay, done, wire), (done, wire)


@functools.partial(jax.jit, static_argnames=("vlb", "num_cycles"))
def _run_batch(adj, own0, vlb: bool, num_cycles: int):
    """vmap(scan(scan)): batch -> cycles -> slices.  Returns cumulative
    delivered/wire trajectories (B, num_cycles*num_slices) and the final
    undelivered residual (B,), all in normalized units."""

    def one_scenario(own_init):
        step = functools.partial(_slice_step, vlb=vlb)

        def one_cycle(carry, _):
            carry, ys = jax.lax.scan(step, carry, adj)
            return carry, ys

        carry0 = (
            own_init,
            jnp.zeros_like(own_init),
            jnp.zeros((), own_init.dtype),
            jnp.zeros((), own_init.dtype),
        )
        (own, relay, _, _), (done_t, wire_t) = jax.lax.scan(
            one_cycle, carry0, None, length=num_cycles
        )
        return done_t.reshape(-1), wire_t.reshape(-1), own.sum() + relay.sum()

    return jax.vmap(one_scenario)(own0)


# --------------------------------------------------------------------------
# Permutation-sparse engine (gather/scatter over matching_index_tensor)
# --------------------------------------------------------------------------

# engine="auto" switches to the sparse gather engine at this rack count:
# the dense relay matmul's O(N^2 u) overtakes the sparse step's
# O(N (N + u)) well below this on paper radixes, but per-step dispatch
# overhead eats the win for small fabrics (benchmarks/perf_track.py
# records the measured crossover PR-over-PR).
SPARSE_AUTO_RACKS = 192


@functools.partial(jax.jit, static_argnames=("vlb",))
def _sparse_slice_step(own, relay, done, wire, dst, vlb: bool):
    """One sparse slice step + trajectory accumulation — the per-step
    device program of the sparse driver.  The slice math lives in
    `kernels.rotor_slice` (Pallas; `ref.rotor_slice_ref` is its oracle
    and mirrors `fluid.rotor_slice_step` / `_slice_step`; change them
    together)."""
    from repro.kernels.rotor_slice.ops import rotor_slice_step

    own, relay, delivered, moved = rotor_slice_step(own, relay, dst, vlb=vlb)
    done = done + delivered
    wire = wire + delivered + moved
    return own, relay, done, wire


def _run_batch_sparse(dst, own0, vlb: bool, num_cycles: int):
    """Sparse analogue of `_run_batch`: same (done_t, wire_t, residual)
    contract, but driven slice-by-slice from the host — one jitted call
    per step.  Deliberately NOT a `lax.scan`: XLA CPU runs the sparse
    step 4-5x slower per step inside a multi-step program (scan or
    unrolled chunks alike) than as a standalone program, while a
    single-step jit call leaves the compare-select chains fused and
    fast.  Per-step dispatch costs microseconds against a
    millisecond-scale step at the rack counts that route here."""
    bsz = own0.shape[0]
    own = own0
    relay = jnp.zeros_like(own0)
    done = jnp.zeros((bsz,), own0.dtype)
    wire = jnp.zeros((bsz,), own0.dtype)
    dst_slices = [dst[t] for t in range(dst.shape[0])]
    done_t, wire_t = [], []
    for _ in range(num_cycles):
        for d in dst_slices:
            own, relay, done, wire = _sparse_slice_step(
                own, relay, done, wire, d, vlb)
            done_t.append(done)
            wire_t.append(wire)
    residual = own.sum((1, 2)) + relay.sum((1, 2))
    return jnp.stack(done_t, 1), jnp.stack(wire_t, 1), residual


@functools.partial(jax.jit, static_argnames=("vlb",))
def _sparse_slice_step_faulted(
    own, relay, done, wire, blk, g, dst, pair_sw,
    up_onset, up_detect, up_recover, tor_onset, tor_detect, tor_recover,
    vlb: bool,
):
    """Faulted sparse step: rebuild the per-step masks from the compiled
    component timelines (same int32 comparisons as
    `_slice_step_faulted`, so masks stay *data* and one lowering serves
    every failure draw), then run the edge-layout faulted math.  Slot s
    of ``dst`` is switch s, so the per-uplink timelines apply directly
    by slot; only the pair-dead relay mask still needs the dense
    ``pair_sw`` serving-switch gather."""
    from repro.kernels.rotor_slice.ref import rotor_slice_faulted_ref

    bsz, n = own.shape[0], own.shape[1]
    u = dst.shape[1]
    up_f = (g >= up_onset) & (g < up_recover)
    up_k = (g >= up_detect) & (g < up_recover)
    tor_fb = (g >= tor_onset) & (g < tor_recover)
    tor_kb = (g >= tor_detect) & (g < tor_recover)
    psw = jnp.broadcast_to(pair_sw[None], (bsz, n, n))
    p_k = jnp.take_along_axis(up_k, psw, axis=2)
    pair_dead = (
        p_k | jnp.swapaxes(p_k, 1, 2)
        | tor_kb[:, :, None] | tor_kb[:, None, :]
    ).astype(own.dtype)
    own, relay, delivered, moved, blackholed = rotor_slice_faulted_ref(
        own, relay, dst, up_f[:, :, :u], up_k[:, :, :u],
        tor_fb, tor_kb, pair_dead, vlb=vlb)
    done = done + delivered
    wire = wire + delivered + moved
    blk = blk + blackholed
    return own, relay, done, wire, blk, g + 1


def _run_batch_sparse_faulted(
    dst, pair_sw, own0,
    up_onset, up_detect, up_recover, tor_onset, tor_detect, tor_recover,
    vlb: bool, num_cycles: int, paced_cycles: int,
):
    """Sparse analogue of `_run_batch_faulted` (same host-side per-step
    driving as `_run_batch_sparse`); returns (done_t, wire_t, residual,
    blackholed)."""
    bsz = own0.shape[0]
    if paced_cycles:
        inject = own0 * (1.0 / paced_cycles)
        own = jnp.zeros_like(own0)
    else:
        own = own0
    relay = jnp.zeros_like(own0)
    done = jnp.zeros((bsz,), own0.dtype)
    wire = jnp.zeros((bsz,), own0.dtype)
    blk = jnp.zeros((bsz,), own0.dtype)
    g = jnp.zeros((), jnp.int32)
    dst_slices = [dst[t] for t in range(dst.shape[0])]
    done_t, wire_t = [], []
    for c in range(num_cycles):
        if paced_cycles and c < paced_cycles:
            own = own + inject
        for d in dst_slices:
            own, relay, done, wire, blk, g = _sparse_slice_step_faulted(
                own, relay, done, wire, blk, g, d, pair_sw,
                up_onset, up_detect, up_recover,
                tor_onset, tor_detect, tor_recover, vlb)
            done_t.append(done)
            wire_t.append(wire)
    residual = own.sum((1, 2)) + relay.sum((1, 2))
    return jnp.stack(done_t, 1), jnp.stack(wire_t, 1), residual, blk


def _slice_step_faulted(state, xs, ops, vlb: bool):
    """One topology slice under failure masks — the faulted scan body.

    Mirrors `fluid.rotor_slice_step_faulted` exactly: per-step masks are
    rebuilt from the compiled component timelines (`faults.step_masks`
    is the numpy reference) with pure int32 comparisons on the global
    step counter carried through the scan — masks are data, so one
    lowering serves every failure draw; change the two together.  With
    an empty schedule every expression reduces algebraically to
    `_slice_step` (x*1.0 / x+0.0), but XLA's fusion-dependent reduction
    order still drifts the last f32 ulp between the two programs — the
    public API dispatches event-less schedules to `_run_batch` so the
    no-op case stays bit-identical (see `_faults_all_empty`).
    """
    own, relay, done, wire, blk, g = state
    adj, sw = xs
    (pair_sw, up_onset, up_detect, up_recover,
     tor_onset, tor_detect, tor_recover) = ops
    up_f = (g >= up_onset) & (g < up_recover)
    up_k = (g >= up_detect) & (g < up_recover)
    tor_fb = (g >= tor_onset) & (g < tor_recover)
    tor_kb = (g >= tor_detect) & (g < tor_recover)
    i_f = jnp.take_along_axis(up_f, sw, axis=1)
    i_k = jnp.take_along_axis(up_k, sw, axis=1)
    e_real = (i_f | i_f.T | tor_fb[:, None] | tor_fb[None, :]).astype(own.dtype)
    e_known = (i_k | i_k.T | tor_kb[:, None] | tor_kb[None, :]).astype(own.dtype)
    p_k = jnp.take_along_axis(up_k, pair_sw, axis=1)
    pair_dead = (
        p_k | p_k.T | tor_kb[:, None] | tor_kb[None, :]
    ).astype(own.dtype)
    tor_real = tor_fb.astype(own.dtype)
    tor_known = tor_kb.astype(own.dtype)

    cap = adj * (1.0 - e_known) * (1.0 - tor_real)[:, None]
    arrive = 1.0 - e_real
    send_own = jnp.minimum(own, cap)
    own = own - send_own * arrive
    room = cap - send_own
    send_relay = jnp.minimum(relay, room)
    relay = relay - send_relay * arrive
    room = room - send_relay
    delivered = (send_own * arrive).sum() + (send_relay * arrive).sum()
    attempted = send_own.sum() + send_relay.sum()
    done = done + delivered
    wire = wire + delivered
    blk = blk + (attempted - delivered)
    if vlb:
        dst_ok = 1.0 - tor_known
        elig = jnp.where(cap > 0, 0.0, own * dst_ok[None, :])
        relig = relay * pair_dead * dst_ok[None, :]  # stuck relay re-spreads
        q = elig.sum(1) + relig.sum(1)
        r = room.sum(1)
        t = jnp.minimum(q, r)
        frac = jnp.where(q > 0, t / jnp.maximum(q, 1e-30), 0.0)[:, None]
        take = elig * frac
        rtake = relig * frac
        share = room * jnp.where(r > 0, 1.0 / jnp.maximum(r, 1e-30), 0.0)[:, None]
        lost = (share * e_real).sum(1)
        own = own - take + take * lost[:, None]
        relay = relay - rtake + rtake * lost[:, None]
        relay = relay + (share * arrive).T @ (take + rtake)
        lost_sum = ((take + rtake).sum(1) * lost).sum()
        wire = wire + (t.sum() - lost_sum)
        blk = blk + lost_sum
    return (own, relay, done, wire, blk, g + 1), (done, wire)


@functools.partial(
    jax.jit, static_argnames=("vlb", "num_cycles", "paced_cycles")
)
def _run_batch_faulted(
    adj, sw, pair_sw, own0,
    up_onset, up_detect, up_recover, tor_onset, tor_detect, tor_recover,
    vlb: bool, num_cycles: int, paced_cycles: int,
):
    """`_run_batch` with per-row failure timelines (and optional paced
    demand injection).  The mask arrays are vmapped scenario operands —
    every batch row carries an independent failure draw — while the
    topology tensor, its switch-id map, and the per-pair serving-switch
    map are shared design-time state.  Also returns the per-row
    blackholed-byte total."""
    def one_scenario(own_init, uo, ud, ur, to, td, tr):
        step = functools.partial(
            _slice_step_faulted, ops=(pair_sw, uo, ud, ur, to, td, tr), vlb=vlb
        )
        if paced_cycles:
            inject = own_init * (1.0 / paced_cycles)
            own_start = jnp.zeros_like(own_init)
        else:
            own_start = own_init

        def one_cycle(carry, c):
            if paced_cycles:
                own, relay, done, wire, blk, g = carry
                own = own + inject * (c < paced_cycles).astype(own.dtype)
                carry = (own, relay, done, wire, blk, g)
            carry, ys = jax.lax.scan(step, carry, (adj, sw))
            return carry, ys

        carry0 = (
            own_start,
            jnp.zeros_like(own_start),
            jnp.zeros((), own_start.dtype),
            jnp.zeros((), own_start.dtype),
            jnp.zeros((), own_start.dtype),
            jnp.zeros((), jnp.int32),
        )
        (own, relay, _, _, blk, _), (done_t, wire_t) = jax.lax.scan(
            one_cycle, carry0, jnp.arange(num_cycles, dtype=jnp.int32)
        )
        return done_t.reshape(-1), wire_t.reshape(-1), own.sum() + relay.sum(), blk

    return jax.vmap(one_scenario)(
        own0, up_onset, up_detect, up_recover,
        tor_onset, tor_detect, tor_recover,
    )


@dataclasses.dataclass
class RotorBatchResult:
    """Per-scenario bulk stats for a batch of B scenarios over T slices.

    Scalars are (B,) arrays; `finished_frac` keeps the full (B, T)
    trajectory (cumulative fraction of demand delivered after each
    slice).  Delivery stats (goodput/wire/throughput/FCT) are read at
    each scenario's completion step `slices_run` — the same truncation
    the numpy oracle's early-exit loop performs."""

    finished_frac: np.ndarray      # (B, T)
    time_us: np.ndarray            # (T,)
    fct_99_ms: np.ndarray          # (B,)
    fct_mean_ms: np.ndarray        # (B,)
    throughput_gbps: np.ndarray    # (B,)
    wire_bytes: np.ndarray         # (B,)
    goodput_bytes: np.ndarray      # (B,)
    residual_bytes: np.ndarray     # (B,) undelivered at scan end
    total_bytes: np.ndarray        # (B,) offered demand
    slices_run: np.ndarray         # (B,)
    blackholed_bytes: Optional[np.ndarray] = None  # (B,) lost-in-flight sends

    @property
    def bandwidth_tax(self) -> np.ndarray:
        return self.wire_bytes / np.maximum(self.goodput_bytes, 1.0) - 1.0

    @property
    def batch_size(self) -> int:
        return self.finished_frac.shape[0]

    def scenario(self, b: int) -> RotorFluidResult:
        """View one batch row as the numpy engine's result type."""
        k = int(self.slices_run[b])
        return RotorFluidResult(
            finished_frac=list(self.finished_frac[b, :k]),
            time_us=list(self.time_us[:k]),
            fct_99_ms=float(self.fct_99_ms[b]),
            fct_mean_ms=float(self.fct_mean_ms[b]),
            throughput_gbps=float(self.throughput_gbps[b]),
            wire_bytes=float(self.wire_bytes[b]),
            goodput_bytes=float(self.goodput_bytes[b]),
            slices_run=k,
            blackholed_bytes=(
                float(self.blackholed_bytes[b])
                if self.blackholed_bytes is not None else 0.0
            ),
        )


def _faults_all_empty(faults) -> bool:
    """True when `faults` carries no failure events at all — None, an
    event-less `FailureSchedule`, or a sequence of event-less ones.
    Empty schedules dispatch to the original failure-free program so
    the no-op case is bit-identical by construction (the faulted
    lowering matches it only to f32 fusion tolerance)."""
    if faults is None:
        return True
    from repro.netsim.faults import FailureSchedule

    if isinstance(faults, FailureSchedule):
        return faults.is_empty
    if isinstance(faults, (list, tuple)):
        return all(
            isinstance(f, FailureSchedule) and f.is_empty for f in faults
        )
    return False


def resolve_engine(engine: str, num_racks: int) -> str:
    """Map ``engine="auto"`` to "dense"/"sparse" by design-point size."""
    if engine == "auto":
        return "sparse" if num_racks >= SPARSE_AUTO_RACKS else "dense"
    if engine not in ("dense", "sparse"):
        raise ValueError(f"engine must be auto|dense|sparse, got {engine!r}")
    return engine


def simulate_rotor_bulk_batch(
    cfg: OperaNetConfig,
    demands: np.ndarray,           # (B, N, N) or (N, N) rack->rack bytes
    vlb: bool = True,
    max_cycles: int = 400,
    topo: Optional[OperaTopology] = None,
    seed: int = 0,
    dtype=jnp.float32,
    faults=None,               # FailureSchedule | Sequence[FailureSchedule]
    paced_cycles: int = 0,
    engine: str = "auto",      # auto | dense | sparse
) -> RotorBatchResult:
    """Simulate a batch of bulk-demand scenarios in one vmapped call.

    All scenarios share one topology (a design point); the batch axis is
    the scenario grid — different workloads, load levels, and demand
    seeds.  Design-point sweeps call this once per point (shapes differ).

    `faults` is a `faults.FailureSchedule` (shared by every row) or a
    sequence of them (one independent draw per row); `paced_cycles`
    spreads each row's demand over that many cycle starts instead of
    offering it all at t=0 — the sustained-load mode the dynamic
    Fig. 11 throughput-retention columns measure.  Both route through
    one faulted lowering per design point; when neither is set the
    original failure-free program runs untouched.

    `engine` selects the dense scan or the permutation-sparse gather
    engine (see module docstring); "auto" picks by rack count.  Within
    either engine an event-less `faults` with no pacing dispatches to
    that engine's unfaulted program, so `FailureSchedule.empty()` stays
    bit-identical to the failure-free run.
    """
    demands = np.asarray(demands, np.float64)  # staticcheck: ok SC-AST-F64 (host staging)
    if demands.ndim == 2:
        demands = demands[None]
    n = cfg.num_racks
    if demands.shape[1:] != (n, n):
        raise ValueError(f"demand shape {demands.shape[1:]} != ({n}, {n})")
    topo = topo or build_opera_topology(n, cfg.u, seed=seed, groups=cfg.groups)
    t = cycle_timing(cfg)
    cap = slice_capacity_bytes(cfg, t)
    engine = resolve_engine(engine, n)

    own0 = jnp.asarray(demands / cap, dtype)
    blackholed = None
    if _faults_all_empty(faults) and not paced_cycles:
        if engine == "sparse":
            dst = jnp.asarray(topo.matching_index_tensor())
            done_t, wire_t, residual = _run_batch_sparse(
                dst, own0, bool(vlb), int(max_cycles))
        else:
            adj = jnp.asarray(topo.matching_tensor(), dtype)
            done_t, wire_t, residual = _run_batch(
                adj, own0, bool(vlb), int(max_cycles))
    else:
        from repro.netsim.faults import (
            FailureSchedule,
            FaultMasks,
            compile_fault_masks,
        )

        if faults is None:
            faults = FailureSchedule.empty(topo)
        masks = (faults if isinstance(faults, FaultMasks)
                 else compile_fault_masks(topo, faults))
        masks = masks.broadcast_to(demands.shape[0])
        if engine == "sparse":
            dst = jnp.asarray(topo.matching_index_tensor())
            done_t, wire_t, residual, blackholed = _run_batch_sparse_faulted(
                dst, jnp.asarray(masks.pair_switch), own0,
                jnp.asarray(masks.up_onset), jnp.asarray(masks.up_detect),
                jnp.asarray(masks.up_recover),
                jnp.asarray(masks.tor_onset), jnp.asarray(masks.tor_detect),
                jnp.asarray(masks.tor_recover),
                bool(vlb), int(max_cycles), int(paced_cycles),
            )
        else:
            adj = jnp.asarray(topo.matching_tensor(), dtype)
            sw = jnp.asarray(masks.switch_id)
            done_t, wire_t, residual, blackholed = _run_batch_faulted(
                adj, sw, jnp.asarray(masks.pair_switch), own0,
                jnp.asarray(masks.up_onset), jnp.asarray(masks.up_detect),
                jnp.asarray(masks.up_recover),
                jnp.asarray(masks.tor_onset), jnp.asarray(masks.tor_detect),
                jnp.asarray(masks.tor_recover),
                bool(vlb), int(max_cycles), int(paced_cycles),
            )
        blackholed = np.asarray(blackholed, np.float64) * cap  # staticcheck: ok SC-AST-F64 (host staging)

    # Device f32 trajectories are de-normalized on the host at float64
    # before stats, mirroring the numpy oracle's precision.
    done = np.asarray(done_t, np.float64) * cap  # staticcheck: ok SC-AST-F64 (host staging)
    wire = np.asarray(wire_t, np.float64) * cap  # staticcheck: ok SC-AST-F64 (host staging)
    residual = np.asarray(residual, np.float64) * cap  # staticcheck: ok SC-AST-F64 (host staging)
    totals = demands.sum((1, 2))

    B, T = done.shape
    time_us = (np.arange(T) + 1) * t.slice_us
    fct99 = np.empty(B)
    fct_mean = np.empty(B)
    tput = np.empty(B)
    slices_run = np.empty(B, np.int64)
    finished = done / np.maximum(totals, 1.0)[:, None]
    for b in range(B):
        hit = done[b] >= totals[b] * 0.99999
        k = int(np.argmax(hit)) if hit.any() else T - 1
        slices_run[b] = k + 1
        fin = finished[b, : k + 1]
        tms = time_us[: k + 1] / 1e3
        fct99[b] = (
            float(tms[np.searchsorted(fin, 0.99)])
            if fin[-1] >= 0.99
            else float("inf")
        )
        fct_mean[b] = float(np.interp(0.5, fin, tms))
        dur_s = time_us[k] * 1e-6
        tput[b] = done[b, k] * 8 / dur_s / 1e9

    rows = np.arange(B)
    at_end = (slices_run - 1).clip(0, T - 1)
    return RotorBatchResult(
        finished_frac=finished,
        time_us=time_us,
        fct_99_ms=fct99,
        fct_mean_ms=fct_mean,
        throughput_gbps=tput,
        wire_bytes=wire[rows, at_end],
        goodput_bytes=done[rows, at_end],
        residual_bytes=residual,
        total_bytes=totals,
        slices_run=slices_run,
        blackholed_bytes=blackholed,
    )


def simulate_rotor_bulk_jax(
    cfg: OperaNetConfig,
    demand: np.ndarray,
    vlb: bool = True,
    max_cycles: int = 400,
    topo: Optional[OperaTopology] = None,
    seed: int = 0,
    faults=None,
    paced_cycles: int = 0,
    engine: str = "auto",
) -> RotorFluidResult:
    """Drop-in single-scenario API (batch of one) matching
    `fluid.simulate_rotor_bulk`'s signature and result type."""
    r = simulate_rotor_bulk_batch(
        cfg, demand, vlb=vlb, max_cycles=max_cycles, topo=topo, seed=seed,
        faults=faults, paced_cycles=paced_cycles, engine=engine,
    )
    return r.scenario(0)
