"""Batched JAX fluid engine for rotor fabrics.

Re-expresses `fluid.simulate_rotor_bulk` as a jitted `lax.scan` over the
dense ``(num_slices, N, N)`` matching tensor exported at design time by
`OperaTopology.matching_tensor`, with `jax.vmap` over a leading batch
axis of scenarios — the (seed x load-level x workload) grids the paper's
bulk figures sweep.  One compiled call simulates the whole batch; the
per-slice recurrence is numerically identical to the numpy oracle
(`fluid.rotor_slice_step`) and the two are lockstep-tested by
tests/test_netsim_jax.py.

Internals: all byte quantities are normalized to units of one
slice-link capacity (`core.schedule.slice_capacity_bytes`) so float32
keeps ample mantissa headroom, and the topology tensor is a scan
operand — no topology math, python branching, or host sync inside the
step.  The scan runs a fixed ``max_cycles`` budget (scenarios that
finish early just stop moving bytes); completion times are recovered
from the cumulative-delivery trajectory on the host afterwards, exactly
as the oracle's early-exit loop records them.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.opera_paper import OperaNetConfig
from repro.core.schedule import cycle_timing, slice_capacity_bytes
from repro.core.topology import OperaTopology, build_opera_topology
from repro.netsim.fluid import RotorFluidResult


def _slice_step(state, adj, vlb: bool):
    """One topology slice, pure jnp — the scan body.

    Mirrors `fluid.rotor_slice_step` exactly (normalized units: every
    live edge's capacity is 1.0); change the two together.
    """
    own, relay, done, wire = state
    send_own = jnp.minimum(own, adj)
    own = own - send_own
    room = adj - send_own
    send_relay = jnp.minimum(relay, room)
    relay = relay - send_relay
    room = room - send_relay
    delivered = send_own.sum() + send_relay.sum()
    done = done + delivered
    wire = wire + delivered
    if vlb:
        elig = jnp.where(adj > 0, 0.0, own)
        q = elig.sum(1)
        r = room.sum(1)
        t = jnp.minimum(q, r)
        take = elig * jnp.where(q > 0, t / jnp.maximum(q, 1e-30), 0.0)[:, None]
        share = room * jnp.where(r > 0, 1.0 / jnp.maximum(r, 1e-30), 0.0)[:, None]
        own = own - take
        relay = relay + share.T @ take
        wire = wire + t.sum()
    return (own, relay, done, wire), (done, wire)


@functools.partial(jax.jit, static_argnames=("vlb", "num_cycles"))
def _run_batch(adj, own0, vlb: bool, num_cycles: int):
    """vmap(scan(scan)): batch -> cycles -> slices.  Returns cumulative
    delivered/wire trajectories (B, num_cycles*num_slices) and the final
    undelivered residual (B,), all in normalized units."""

    def one_scenario(own_init):
        step = functools.partial(_slice_step, vlb=vlb)

        def one_cycle(carry, _):
            carry, ys = jax.lax.scan(step, carry, adj)
            return carry, ys

        carry0 = (
            own_init,
            jnp.zeros_like(own_init),
            jnp.zeros((), own_init.dtype),
            jnp.zeros((), own_init.dtype),
        )
        (own, relay, _, _), (done_t, wire_t) = jax.lax.scan(
            one_cycle, carry0, None, length=num_cycles
        )
        return done_t.reshape(-1), wire_t.reshape(-1), own.sum() + relay.sum()

    return jax.vmap(one_scenario)(own0)


@dataclasses.dataclass
class RotorBatchResult:
    """Per-scenario bulk stats for a batch of B scenarios over T slices.

    Scalars are (B,) arrays; `finished_frac` keeps the full (B, T)
    trajectory (cumulative fraction of demand delivered after each
    slice).  Delivery stats (goodput/wire/throughput/FCT) are read at
    each scenario's completion step `slices_run` — the same truncation
    the numpy oracle's early-exit loop performs."""

    finished_frac: np.ndarray      # (B, T)
    time_us: np.ndarray            # (T,)
    fct_99_ms: np.ndarray          # (B,)
    fct_mean_ms: np.ndarray        # (B,)
    throughput_gbps: np.ndarray    # (B,)
    wire_bytes: np.ndarray         # (B,)
    goodput_bytes: np.ndarray      # (B,)
    residual_bytes: np.ndarray     # (B,) undelivered at scan end
    total_bytes: np.ndarray        # (B,) offered demand
    slices_run: np.ndarray         # (B,)

    @property
    def bandwidth_tax(self) -> np.ndarray:
        return self.wire_bytes / np.maximum(self.goodput_bytes, 1.0) - 1.0

    @property
    def batch_size(self) -> int:
        return self.finished_frac.shape[0]

    def scenario(self, b: int) -> RotorFluidResult:
        """View one batch row as the numpy engine's result type."""
        k = int(self.slices_run[b])
        return RotorFluidResult(
            finished_frac=list(self.finished_frac[b, :k]),
            time_us=list(self.time_us[:k]),
            fct_99_ms=float(self.fct_99_ms[b]),
            fct_mean_ms=float(self.fct_mean_ms[b]),
            throughput_gbps=float(self.throughput_gbps[b]),
            wire_bytes=float(self.wire_bytes[b]),
            goodput_bytes=float(self.goodput_bytes[b]),
            slices_run=k,
        )


def simulate_rotor_bulk_batch(
    cfg: OperaNetConfig,
    demands: np.ndarray,           # (B, N, N) or (N, N) rack->rack bytes
    vlb: bool = True,
    max_cycles: int = 400,
    topo: Optional[OperaTopology] = None,
    seed: int = 0,
    dtype=jnp.float32,
) -> RotorBatchResult:
    """Simulate a batch of bulk-demand scenarios in one vmapped call.

    All scenarios share one topology (a design point); the batch axis is
    the scenario grid — different workloads, load levels, and demand
    seeds.  Design-point sweeps call this once per point (shapes differ).
    """
    demands = np.asarray(demands, np.float64)  # staticcheck: ok SC-AST-F64 (host staging)
    if demands.ndim == 2:
        demands = demands[None]
    n = cfg.num_racks
    if demands.shape[1:] != (n, n):
        raise ValueError(f"demand shape {demands.shape[1:]} != ({n}, {n})")
    topo = topo or build_opera_topology(n, cfg.u, seed=seed, groups=cfg.groups)
    t = cycle_timing(cfg)
    cap = slice_capacity_bytes(cfg, t)

    adj = jnp.asarray(topo.matching_tensor(), dtype)
    own0 = jnp.asarray(demands / cap, dtype)
    done_t, wire_t, residual = _run_batch(adj, own0, bool(vlb), int(max_cycles))

    # Device f32 trajectories are de-normalized on the host at float64
    # before stats, mirroring the numpy oracle's precision.
    done = np.asarray(done_t, np.float64) * cap  # staticcheck: ok SC-AST-F64 (host staging)
    wire = np.asarray(wire_t, np.float64) * cap  # staticcheck: ok SC-AST-F64 (host staging)
    residual = np.asarray(residual, np.float64) * cap  # staticcheck: ok SC-AST-F64 (host staging)
    totals = demands.sum((1, 2))

    B, T = done.shape
    time_us = (np.arange(T) + 1) * t.slice_us
    fct99 = np.empty(B)
    fct_mean = np.empty(B)
    tput = np.empty(B)
    slices_run = np.empty(B, np.int64)
    finished = done / np.maximum(totals, 1.0)[:, None]
    for b in range(B):
        hit = done[b] >= totals[b] * 0.99999
        k = int(np.argmax(hit)) if hit.any() else T - 1
        slices_run[b] = k + 1
        fin = finished[b, : k + 1]
        tms = time_us[: k + 1] / 1e3
        fct99[b] = (
            float(tms[np.searchsorted(fin, 0.99)])
            if fin[-1] >= 0.99
            else float("inf")
        )
        fct_mean[b] = float(np.interp(0.5, fin, tms))
        dur_s = time_us[k] * 1e-6
        tput[b] = done[b, k] * 8 / dur_s / 1e9

    rows = np.arange(B)
    at_end = (slices_run - 1).clip(0, T - 1)
    return RotorBatchResult(
        finished_frac=finished,
        time_us=time_us,
        fct_99_ms=fct99,
        fct_mean_ms=fct_mean,
        throughput_gbps=tput,
        wire_bytes=wire[rows, at_end],
        goodput_bytes=done[rows, at_end],
        residual_bytes=residual,
        total_bytes=totals,
        slices_run=slices_run,
    )


def simulate_rotor_bulk_jax(
    cfg: OperaNetConfig,
    demand: np.ndarray,
    vlb: bool = True,
    max_cycles: int = 400,
    topo: Optional[OperaTopology] = None,
    seed: int = 0,
) -> RotorFluidResult:
    """Drop-in single-scenario API (batch of one) matching
    `fluid.simulate_rotor_bulk`'s signature and result type."""
    r = simulate_rotor_bulk_batch(
        cfg, demand, vlb=vlb, max_cycles=max_cycles, topo=topo, seed=seed
    )
    return r.scenario(0)
