"""repro — Opera ("Expanding across time", Mellette et al. 2019) in JAX.

A multi-pod training/serving framework whose communication layer is the
paper's time-expanded rotor/expander scheduling, plus a flow-level network
simulator reproducing the paper's own evaluation.
"""

__version__ = "1.0.0"
