"""Roofline terms from a compiled dry-run artifact (TPU v5e constants).

    compute    = HLO_FLOPs_per_device   / 197e12   [s]
    memory     = HLO_bytes_per_device   / 819e9    [s]
    collective = wire_bytes_per_device  / 50e9     [s]  (per-ICI-link model)

All three are per-device quantities over per-chip rates, i.e. exactly
FLOPs_total/(chips*peak) etc. since SPMD devices are symmetric.  The
dominant term is the projected step-time floor; roofline fraction =
dominant / sum proxies how far from balanced the cell is.  MODEL_FLOPS
(6*N*D train, 2*N*D decode/prefill forward) over HLO FLOPs measures how
much compiled compute is "useful" (catches remat/causal-mask/dispatch
waste).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12       # bf16 / chip (TPU v5e)
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / ICI link


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops_total: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.flops_per_device / PEAK_FLOPS
        self.memory_s = self.bytes_per_device / HBM_BW
        self.collective_s = self.coll_bytes_per_device / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_floor_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total_hlo = self.flops_per_device * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def mfu_at_floor(self) -> float:
        """Model FLOPs / (chips * peak * step_floor): the MFU the compiled
        program would achieve if it ran exactly at the roofline floor."""
        denom = self.chips * PEAK_FLOPS * self.step_floor_s
        return self.model_flops_total / denom if denom else 0.0

    def row(self) -> Dict:
        return dict(
            arch=self.arch,
            shape=self.shape,
            mesh=self.mesh,
            chips=self.chips,
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            dominant=self.dominant,
            model_flops=self.model_flops_total,
            hlo_flops_total=self.flops_per_device * self.chips,
            useful_ratio=self.useful_flops_ratio,
            mfu_at_floor=self.mfu_at_floor,
        )


def model_flops(cfg, shape, n_params: int, n_active: int) -> float:
    """6*N*D for train, 2*N*D for forward-only (prefill), 2*N per token
    for decode (D = tokens processed)."""
    B, S = shape.global_batch, shape.seq_len
    n = n_active or n_params
    if shape.kind == "train":
        return 6.0 * n * B * S
    if shape.kind == "prefill":
        return 2.0 * n * B * S
    return 2.0 * n * B  # decode: one token per sequence


def fmt_table(rows) -> str:
    hdr = (
        f"{'arch':24s} {'shape':12s} {'mesh':9s} {'compute':>10s} "
        f"{'memory':>10s} {'collective':>11s} {'dominant':>10s} "
        f"{'useful':>7s} {'MFU@floor':>9s}"
    )
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        out.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:9s} "
            f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
            f"{r['collective_s']:11.4f} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.3f} {r['mfu_at_floor']:9.3f}"
        )
    return "\n".join(out)
