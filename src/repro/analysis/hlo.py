"""Post-optimization HLO parsing: per-device collective wire bytes.

GSPMD-inserted collectives only exist *after* partitioning, so we parse
``compiled.as_text()`` (the per-device SPMD module).  For each
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op we take the RESULT shape bytes as the per-device wire-byte proxy
(all-reduce/all-to-all/permute: payload size; all-gather: bytes received;
reduce-scatter: bytes retained after reducing N-1 remote shards).  Tuple
results (variadic collectives) sum their components.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g. "bf16[16,512]{1,0}" or "f32[]"
_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# "%x.1 = <type> <op>(" where op is a collective (possibly -start/-done)
_LINE_RE = re.compile(
    r"=\s*(.+?)\s+(" + "|".join(_COLLECTIVES) + r")(?:-start)?\("
)


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind (+ op counts)."""
    by_kind: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for m in _LINE_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        b = _type_bytes(type_str)
        by_kind[kind] += b
        counts[kind] += 1
    out = {f"bytes_{k}": v for k, v in by_kind.items()}
    out.update({f"count_{k}": float(v) for k, v in counts.items()})
    out["bytes_total"] = float(sum(by_kind.values()))
    out["count_total"] = float(sum(counts.values()))
    return dict(out)


def collective_breakdown_table(hlo_text: str) -> str:
    d = collective_bytes(hlo_text)
    rows = ["kind            count       bytes"]
    for k in _COLLECTIVES:
        c = int(d.get(f"count_{k}", 0))
        b = d.get(f"bytes_{k}", 0.0)
        if c:
            rows.append(f"{k:15s} {c:5d} {b:12.3e}")
    rows.append(f"{'TOTAL':15s} {int(d['count_total']):5d} {d['bytes_total']:12.3e}")
    return "\n".join(rows)
