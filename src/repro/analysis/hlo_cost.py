"""Loop-aware HLO cost model.

XLA's built-in ``compiled.cost_analysis()`` visits every instruction ONCE,
so anything inside a ``while`` loop (every ``lax.scan`` — our layer stacks,
chunked attention, SSM chunk scans) is undercounted by its trip count, and
collectives inside scanned layers are likewise missed by naive text
grepping.  This module parses the post-optimization HLO text into its
computation graph and computes, bottom-up:

    flops       — dot (2*out*contract), elementwise (1/elem), reduce
    bytes       — operand+output bytes at thunk level; fusions count only
                  their boundary (operands+output), matching HloCostAnalysis
    coll_bytes  — per-kind wire bytes of collective ops

with ``while`` costs multiplied by the trip count recovered from the loop
condition (scan-generated loops compare an induction variable against a
constant).  Validated against unrolled references in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BDIMS = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "tanh", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "compare", "select", "clamp",
    "and", "or", "xor", "not", "sine", "cosine", "tan", "atan2", "logistic",
    "remainder", "is-finite", "erf", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "popcnt",
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_ZERO_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "after-all", "partition-id", "replica-id", "iota",
    "get-dimension-size", "custom-call",
}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems = 0
    nbytes = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str          # operand list + attributes (raw tail of the line)

    @property
    def operands(self) -> List[str]:
        # operands live before the closing paren of the op call; attributes
        # follow — but operand names are unambiguous %refs in the tail's
        # first paren group.  We scan up to the matching close paren.
        depth = 1
        out = []
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    out = _OPERAND_RE.findall(self.rest[:i])
                    break
        return out


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)
    root_opcode: str = ""


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(2))
                # parameters appear as instrs too; types captured there
            continue
        s = line.strip()
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.instrs.append(ins)
            cur.types[ins.name] = ins.type_str
            if s.startswith("ROOT"):
                cur.root_opcode = ins.opcode
    return comps


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v * mult


def _sliced_param_bytes(comp: Computation) -> Dict[int, int]:
    """Parameters of a fused computation whose ONLY uses are
    dynamic-slice/gather: return {param_index: total sliced bytes}."""
    params: Dict[str, int] = {}
    for ins in comp.instrs:
        if ins.opcode == "parameter":
            m = re.match(r"(\d+)", ins.rest)
            if m:
                params[ins.name] = int(m.group(1))
    out: Dict[int, int] = {}
    use_ok: Dict[str, bool] = {n: True for n in params}
    sliced: Dict[str, int] = {n: 0 for n in params}
    for ins in comp.instrs:
        if ins.opcode == "parameter":
            continue
        ops = ins.operands
        for n in params:
            if n in ops:
                if ins.opcode in ("dynamic-slice", "gather") and ops and ops[0] == n:
                    _, b = _shape_elems_bytes(ins.type_str)
                    sliced[n] += b
                else:
                    use_ok[n] = False
    for n, idx in params.items():
        if use_ok[n] and sliced[n] > 0:
            out[idx] = sliced[n]
    return out


def _trip_count(cond: Computation) -> int:
    consts = []
    for ins in cond.instrs:
        consts += [int(c) for c in _CONST_RE.findall(ins.rest)]
        consts += [int(c) for c in _CONST_RE.findall(ins.opcode)] if False else []
    # also catch "constant(N)" appearing as its own instruction:
    return max(consts) if consts else 1


def _instr_cost(
    ins: Instr, comp: Computation, comps: Dict[str, Computation],
    memo: Dict[str, Cost], in_fusion: bool,
) -> Cost:
    c = Cost()
    op = ins.opcode
    base = op[:-6] if op.endswith("-start") else op
    out_elems, out_bytes = _shape_elems_bytes(ins.type_str)

    # ---- flops ----
    if base == "dot":
        lhs_name = ins.operands[0] if ins.operands else None
        contract = 1
        if lhs_name and lhs_name in comp.types:
            dims_str = _LHS_CDIMS.search(ins.rest)
            m = _ARRAY_RE.search(comp.types[lhs_name])
            if dims_str and m:
                shape = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
                for di in (int(x) for x in dims_str.group(1).split(",") if x):
                    if di < len(shape):
                        contract *= shape[di]
        c.flops += 2.0 * out_elems * contract
    elif base in _ELEMWISE:
        c.flops += float(out_elems)
    elif base in ("reduce", "reduce-window"):
        in_elems = 0
        for opn in ins.operands:
            e, _ = _shape_elems_bytes(comp.types.get(opn, ""))
            in_elems += e
        c.flops += float(in_elems)
    elif base == "convolution":
        c.flops += 2.0 * out_elems  # lower bound; convs unused in this repo

    # ---- bytes ----
    if not in_fusion and base not in _ZERO_BYTES:
        ops_b = [
            _shape_elems_bytes(comp.types.get(opn, ""))[1]
            for opn in ins.operands
        ]
        # operands that the fused computation only ever *slices* (the
        # per-layer parameter reads of a scan over stacked weights) are
        # charged at slice size, not full-buffer size
        if base == "fusion":
            mm = _CALLS_RE.search(ins.rest)
            if mm and mm.group(1) in comps:
                sliced = _sliced_param_bytes(comps[mm.group(1)])
                for i in range(min(len(ops_b), 16)):
                    if i in sliced:
                        ops_b[i] = min(ops_b[i], sliced[i])
        opb = sum(ops_b)
        # in-place update semantics: a dynamic-update-slice (raw or as a
        # fusion root, i.e. every lax.scan accumulator / KV-cache write)
        # touches only the updated slice, not the whole buffer — XLA
        # aliases input/output.  Without this, scan output collection is
        # counted quadratically (trip x full buffer) and swamps the
        # memory roofline term (see EXPERIMENTS.md §Perf, iteration 0).
        rooted = base
        if base == "fusion":
            mm = _CALLS_RE.search(ins.rest)
            if mm and mm.group(1) in comps:
                rooted = comps[mm.group(1)].root_opcode
        if rooted == "dynamic-update-slice" and ops_b:
            update = max(opb - max(ops_b), 0)
            c.bytes += 2.0 * update  # read update, write slice
        elif rooted in ("dynamic-slice", "gather"):
            c.bytes += 2.0 * out_bytes  # read slice, write out
        else:
            c.bytes += opb + out_bytes

    # ---- collectives ----
    if base in _COLLECTIVES:
        c.coll[base] = c.coll.get(base, 0.0) + out_bytes
        c.coll_counts[base] = c.coll_counts.get(base, 0.0) + 1

    # ---- called computations ----
    if base == "fusion":
        m = _CALLS_RE.search(ins.rest)
        if m and m.group(1) in comps:
            sub = _comp_cost(comps[m.group(1)], comps, memo, in_fusion=True)
            c.flops += sub.flops
            # fusion bytes = boundary only (already counted above)
            for k, v in sub.coll.items():
                c.coll[k] = c.coll.get(k, 0.0) + v
    elif base == "while":
        mb, mc = _BODY_RE.search(ins.rest), _COND_RE.search(ins.rest)
        if mb and mb.group(1) in comps:
            mt = _TRIP_RE.search(ins.rest)
            if mt:
                trip = int(mt.group(1))
            elif mc and mc.group(1) in comps:
                trip = _trip_count(comps[mc.group(1)])
            else:
                trip = 1
            body = _comp_cost(comps[mb.group(1)], comps, memo, in_fusion)
            c.add(body, mult=float(trip))
    elif base in ("call", "async-start", "conditional"):
        for m in _CALLS_RE.finditer(ins.rest):
            if m.group(1) in comps:
                c.add(_comp_cost(comps[m.group(1)], comps, memo, in_fusion))
    # reduce's to_apply is per-element scalar math; covered by in_elems.
    return c


def _comp_cost(
    comp: Computation, comps: Dict[str, Computation],
    memo: Dict[str, Cost], in_fusion: bool = False,
) -> Cost:
    key = f"{comp.name}|{in_fusion}"
    if key in memo:
        return memo[key]
    total = Cost()
    memo[key] = total  # break cycles defensively
    for ins in comp.instrs:
        total.add(_instr_cost(ins, comp, comps, memo, in_fusion))
    return total


def analyze(hlo_text: str) -> Dict[str, float]:
    """Loop-aware per-device cost of the entry computation."""
    comps = parse_hlo(hlo_text)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.M)
    if m:
        entry = comps.get(m.group(1))
    if entry is None:  # fall back: the largest computation
        entry = max(comps.values(), key=lambda c: len(c.instrs))
    memo: Dict[str, Cost] = {}
    c = _comp_cost(entry, comps, memo)
    out = {
        "flops": c.flops,
        "bytes": c.bytes,
        "coll_bytes_total": float(sum(c.coll.values())),
        "coll_count_total": float(sum(c.coll_counts.values())),
    }
    for k, v in c.coll.items():
        out[f"coll_bytes_{k}"] = v
    for k, v in c.coll_counts.items():
        out[f"coll_count_{k}"] = v
    return out


def top_instructions(hlo_text: str, k: int = 20):
    """Heaviest instructions by loop-multiplied bytes (profile substitute).

    Walks the computation graph with the same trip-count multipliers as
    analyze(), attributing each thunk-level instruction's bytes/flops,
    and returns the top-k — the dry-run analog of a memory profile.
    """
    comps = parse_hlo(hlo_text)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.M)
    if m:
        entry = comps.get(m.group(1))
    if entry is None:
        entry = max(comps.values(), key=lambda c: len(c.instrs))

    rows = []

    def walk(comp: Computation, mult: float, in_fusion: bool):
        for ins in comp.instrs:
            base = ins.opcode[:-6] if ins.opcode.endswith("-start") else ins.opcode
            c = Cost()
            # per-instruction own cost (no recursion)
            out_elems, out_bytes = _shape_elems_bytes(ins.type_str)
            if not in_fusion and base not in _ZERO_BYTES:
                opb = sum(
                    _shape_elems_bytes(comp.types.get(o, ""))[1]
                    for o in ins.operands
                )
                c.bytes = opb + out_bytes
            if base == "fusion":
                mm = _CALLS_RE.search(ins.rest)
                if mm and mm.group(1) in comps:
                    sub = _comp_cost(comps[mm.group(1)], comps, {}, True)
                    c.flops += sub.flops
            if c.bytes or c.flops:
                meta = re.search(r'op_name="([^"]*)"', ins.rest)
                rows.append(dict(
                    name=ins.name, op=base, mult=mult,
                    bytes=c.bytes * mult, flops=c.flops * mult,
                    op_name=meta.group(1)[-90:] if meta else "",
                ))
            if base == "while":
                mb = _BODY_RE.search(ins.rest)
                mc = _COND_RE.search(ins.rest)
                mt = _TRIP_RE.search(ins.rest)
                trip = int(mt.group(1)) if mt else (
                    _trip_count(comps[mc.group(1)]) if mc and mc.group(1) in comps else 1
                )
                if mb and mb.group(1) in comps:
                    walk(comps[mb.group(1)], mult * trip, in_fusion)
            elif base in ("call", "conditional"):
                for mm in _CALLS_RE.finditer(ins.rest):
                    if mm.group(1) in comps:
                        walk(comps[mm.group(1)], mult, in_fusion)

    walk(entry, 1.0, False)
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:k]
