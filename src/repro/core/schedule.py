"""Cycle-time model (§4.1, §3.5, Appendix B).

Reproduces the paper's timing arithmetic for any design point:

  epsilon  = worst-case end-to-end delay under worst-case queuing
  slice    = epsilon + r                      (r = reconfiguration delay)
  per-switch period = (u/groups) * slice      ("about 6 eps" for the 648-host point)
  duty cycle = 1 - r / per-switch period      (98 %)
  cycle    = num_slices * slice               (10.7 ms)
  bulk cutoff ~ link_rate * cycle             (flows that amortize one cycle)

plus the guard-band sensitivities quoted in §3.5 (1 %/us low-latency,
0.2 %/us bulk).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.opera_paper import OperaNetConfig


@dataclasses.dataclass(frozen=True)
class CycleTiming:
    epsilon_us: float
    reconfig_us: float
    slice_us: float
    per_switch_period_us: float
    duty_cycle: float
    num_slices: int
    cycle_ms: float
    bulk_cutoff_mb: float
    ll_capacity_loss_per_guard_us: float
    bulk_capacity_loss_per_guard_us: float


def epsilon_us(
    worst_hops: int,
    queue_bytes: int,
    link_rate_gbps: float,
    prop_delay_us: float,
    mtu: int = 1500,
) -> float:
    """Worst-case end-to-end delay: at each of `worst_hops` ToR-to-ToR hops
    a packet may wait behind a full shallow queue, plus serialization and
    propagation. (§4.1: 24 KB queue, 5 hops, 500 ns, 10 Gb/s -> 90 us.)"""
    drain_us = queue_bytes * 8 / (link_rate_gbps * 1e3)  # us
    ser_us = mtu * 8 / (link_rate_gbps * 1e3)
    # the paper quotes 90 us for 5 hops; per-hop budget is dominated by the
    # queue drain (19.2 us) — the residual is propagation+serialization.
    per_hop = drain_us - ser_us + prop_delay_us + ser_us
    return worst_hops * per_hop


def cycle_timing(cfg: OperaNetConfig, worst_hops: int = 5) -> CycleTiming:
    eps = epsilon_us(
        worst_hops, cfg.queue_bytes, cfg.link_rate_gbps, cfg.prop_delay_us, cfg.mtu
    )
    slice_us = eps + cfg.reconfig_delay_us
    rounds = cfg.u // cfg.groups
    per_switch = rounds * slice_us
    duty = 1.0 - cfg.reconfig_delay_us / per_switch
    num_slices = cfg.num_racks * cfg.u // cfg.u // cfg.groups  # N/groups
    num_slices = cfg.num_racks // cfg.groups
    cycle_ms = num_slices * slice_us / 1e3
    # a bulk flow must amortize waiting <= one cycle for its direct slice:
    # FCT within 2x ideal requires size/rate >= cycle (§4.1 -> ~15 MB).
    cutoff_mb = cfg.link_rate_gbps * 1e9 / 8 * (cycle_ms / 1e3) / 2**20
    return CycleTiming(
        epsilon_us=eps,
        reconfig_us=cfg.reconfig_delay_us,
        slice_us=slice_us,
        per_switch_period_us=per_switch,
        duty_cycle=duty,
        num_slices=num_slices,
        cycle_ms=cycle_ms,
        bulk_cutoff_mb=cutoff_mb,
        # each us of guard band removes g/slice of low-latency airtime ...
        ll_capacity_loss_per_guard_us=1.0 / slice_us,
        # ... and g/per_switch_period of a circuit's bulk airtime
        bulk_capacity_loss_per_guard_us=1.0 / per_switch,
    )


def slice_capacity_bytes(cfg: OperaNetConfig, timing: CycleTiming = None) -> float:
    """Byte budget of one live circuit during one slice (duty-derated).

    A plain python float on purpose: both fluid engines (numpy reference
    and the jnp/scan batched engine) consume it as a static scalar, so it
    never becomes a traced value and the jitted step stays shape-stable.
    """
    t = timing or cycle_timing(cfg)
    return cfg.link_rate_gbps * 1e9 / 8 * (t.slice_us * 1e-6) * t.duty_cycle


def scaled_cycle_table(k_values=(12, 24, 36, 48, 64), groups_of: int = 6) -> list:
    """Appendix B: grouped reconfiguration keeps cycle time ~linear in k.

    k-radix ToR -> u = k/2 switches, N = racks scale ~ (k/2)*(k/2)*3 (the
    paper's 648-host k=12 -> 108-rack point scales to 98,304 hosts at
    k=64).  We reproduce the relative-cycle-time trend of Fig. 14."""
    rows = []
    base = None
    for k in k_values:
        u = k // 2
        scale = (k // 12) ** 2
        racks = 108 * scale
        groups = max(1, u // groups_of)
        cfg = OperaNetConfig(
            name=f"opera-k{k}",
            k=k,
            num_racks=racks,
            hosts_per_rack=k // 2,
            num_circuit_switches=u,
            groups=groups,
        )
        t = cycle_timing(cfg)
        if base is None:
            base = t.cycle_ms
        rows.append(
            dict(
                k=k,
                racks=racks,
                hosts=racks * (k // 2),
                switches=u,
                groups=groups,
                cycle_ms=t.cycle_ms,
                relative_cycle=t.cycle_ms / base,
                bulk_cutoff_mb=t.bulk_cutoff_mb,
            )
        )
    return rows
