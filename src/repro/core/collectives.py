"""Rotor collectives — Opera's time-expanded scheduling as JAX collectives.

The paper's bulk class buffers traffic until the rotor switches provide a
*direct* source->destination circuit, so every byte crosses exactly one
link (zero bandwidth tax).  On a TPU mesh axis of size N the analog is the
N-matching sum-factorization of the complete graph (core.topology): during
"slice" m shard i exchanges exactly with (m - i) mod N.  A rotor collective
walks the slices with one `lax.ppermute` per matching, moving each peer's
chunk on the one slice with a direct circuit.

The latency class is the opposite trade: don't wait, hop over the
currently-live expander (multi-hop `ppermute` chains), paying the
bandwidth tax in exchange for immediacy.  `expander_all_gather` implements
it; it is the right primitive for small control tensors (loss scalars,
router statistics, health beacons).

All functions here are *per-shard* code: they must be called inside
`shard_map` (or any context with the named axis bound).  Pure-jnp
reference semantics used by the tests:

    rotor_all_reduce(x, ax)        == lax.psum(x, ax)
    rotor_reduce_scatter(x, ax)    == lax.psum_scatter(x, ax, tiled-chunk)
    rotor_all_gather(x, ax)        == lax.all_gather(x, ax)
    rotor_all_to_all(x, ax)        == lax.all_to_all(x, ax, 0, 0, tiled=..)

Everything is schedule-static: matchings are computed at trace time from
the axis size (design-time, like the paper — no runtime circuit selection).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import topology as topo


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def _matchings(n: int) -> list[np.ndarray]:
    """All n sum-factorization matchings (partner vectors)."""
    return topo.sum_matchings(n)


def _perm_pairs(p: np.ndarray) -> list[tuple[int, int]]:
    return [(int(i), int(p[i])) for i in range(len(p)) if int(p[i]) != i]


def _axis_size(axis_name) -> int:
    from repro.compat import axis_size

    return axis_size(axis_name)


def _split_leading(x: jnp.ndarray, n: int) -> jnp.ndarray:
    """Reshape to (n, chunk) over a flattened view; requires divisibility."""
    flat = x.reshape(-1)
    if flat.shape[0] % n != 0:
        pad = n - flat.shape[0] % n
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(n, -1)


# --------------------------------------------------------------------------
# bulk class: direct one-hop schedules
# --------------------------------------------------------------------------


def rotor_reduce_scatter(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    """Reduce-scatter: every shard ends with the fully-reduced chunk i.

    Each addend chunk travels exactly one hop (its direct slice) — Opera's
    bulk class.  Input may be any shape; it is flattened to (N, chunk) and
    the local reduced chunk (chunk,) is returned.
    """
    n = _axis_size(axis_name)
    i = lax.axis_index(axis_name)
    xs = _split_leading(x, n)
    acc = jnp.take(xs, i, axis=0)
    for p in _matchings(n):
        pairs = _perm_pairs(p)
        if not pairs:
            continue
        partner = jnp.asarray(p, dtype=jnp.int32)[i]
        # send the chunk destined for my partner; receive mine from them
        payload = jnp.take(xs, partner, axis=0)
        recv = lax.ppermute(payload, axis_name, pairs)
        # fixed-point shards receive zeros; adding them is a no-op
        acc = acc + jnp.where(partner == i, jnp.zeros_like(recv), recv)
    return acc


def rotor_all_gather(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    """All-gather of per-shard chunks, one direct hop per chunk."""
    n = _axis_size(axis_name)
    i = lax.axis_index(axis_name)
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = out.at[i].set(x)
    for p in _matchings(n):
        pairs = _perm_pairs(p)
        if not pairs:
            continue
        partner = jnp.asarray(p, dtype=jnp.int32)[i]
        recv = lax.ppermute(x, axis_name, pairs)
        val = jnp.where((partner == i), x, recv)
        out = out.at[partner].set(val)
    return out


def rotor_all_reduce(
    x: jnp.ndarray, axis_name, mode: str = "rs_ag"
) -> jnp.ndarray:
    """All-reduce via the rotor schedule.

    mode="rs_ag": reduce-scatter + all-gather (2 one-hop journeys/byte,
                  2*(N-1)/N * |x| bytes on the wire per shard — bandwidth
                  optimal, the beyond-paper default).
    mode="direct": every slice exchanges the *whole* tensor with the direct
                  partner ((N-1) * |x| bytes; fewer rounds, optimal for
                  small N, e.g. the 2-pod axis).
    """
    if mode == "direct":
        acc = x
        n = _axis_size(axis_name)
        i = lax.axis_index(axis_name)
        for p in _matchings(n):
            pairs = _perm_pairs(p)
            if not pairs:
                continue
            partner = jnp.asarray(p, dtype=jnp.int32)[i]
            recv = lax.ppermute(x, axis_name, pairs)
            acc = acc + jnp.where(partner == i, jnp.zeros_like(recv), recv)
        return acc
    shape, size = x.shape, x.size
    chunk = rotor_reduce_scatter(x, axis_name)
    full = rotor_all_gather(chunk, axis_name).reshape(-1)
    return full[:size].reshape(shape)


def rotor_all_to_all(
    x: jnp.ndarray, axis_name, vlb: bool = False
) -> jnp.ndarray:
    """All-to-all: x has leading dim N (chunk j is destined for shard j);
    returns the same layout with chunk j originating from shard j.

    vlb=True adds RotorLB's 2-hop Valiant spreading: every chunk first
    hops to a balanced intermediate and is delivered on the next "cycle".
    That doubles wire bytes (the paper's 100 % VLB tax) but decouples the
    per-slice load from the demand skew: with skewed chunks (a few hot
    destinations) direct scheduling idles most slices while VLB keeps
    every slice busy.
    """
    n = _axis_size(axis_name)
    if x.shape[0] != n:
        raise ValueError(f"leading dim {x.shape[0]} != axis size {n}")
    i = lax.axis_index(axis_name)

    def one_round(buf):
        out = jnp.zeros_like(buf)
        out = out.at[i].set(buf[i])
        for p in _matchings(n):
            pairs = _perm_pairs(p)
            if not pairs:
                continue
            partner = jnp.asarray(p, dtype=jnp.int32)[i]
            payload = jnp.take(buf, partner, axis=0)
            recv = lax.ppermute(payload, axis_name, pairs)
            val = jnp.where(partner == i, buf[i], recv)
            out = out.at[partner].set(val)
        return out

    if not vlb:
        return one_round(x)
    # phase 1: spread — chunk destined to d goes to intermediate (d+i)%n
    # (balanced: each intermediate receives exactly one chunk per source),
    # i.e. the phase-1 buffer row m (intermediate m) carries x[(m - i) % n].
    perm_rows = (jnp.arange(n) - i) % n
    spread = jnp.take(x, perm_rows, axis=0)  # buffer indexed by intermediate
    at_inter = one_round(spread)
    # at_inter[s] = chunk from source s whose final dest is (idx... recover:
    # source s sent us (i) the chunk for dest d with (d + s) % n == i
    dests = (i - jnp.arange(n)) % n  # dest of the chunk received from source s
    # phase 2: deliver — rebucket rows by final dest, then one more round.
    deliver = jnp.zeros_like(at_inter)
    deliver = deliver.at[dests].set(at_inter)
    out = one_round(deliver)
    # out[s'] now holds, from each intermediate s', the chunk destined to us;
    # rebucket rows by ORIGINAL source: the chunk we got via intermediate s'
    # originated at source (s' - ... ) — recover source from the phase-1 rule:
    # src s chose intermediate (i_dest + s) % n ... for our dest row d == us,
    # intermediate m carried the chunk of source (m - i) % n? phase1: src s,
    # dest us: intermediate = (us + s) % n = m -> s = (m - i) % n.
    srcs = (jnp.arange(n) - i) % n
    final = jnp.zeros_like(out)
    final = final.at[srcs].set(out)
    return final


# --------------------------------------------------------------------------
# latency class: immediate multi-hop over the live expander
# --------------------------------------------------------------------------


def _expander_routing(n: int, u: int, seed: int = 0):
    """Static design-time routing over the union of u live matchings.

    Returns (matchings, diameter).  Like the paper, if a random draw is a
    poor expander we redraw at design time (§3.3).
    """
    from repro.core.expander import hop_distances

    for attempt in range(16):
        ms = topo.random_matchings(n, seed + attempt)
        i = np.arange(n)
        live = [p for p in ms if (p != i).any()][:u]
        adj = np.zeros((n, n), dtype=bool)
        for p in live:
            mask = p != i
            adj[i[mask], p[mask]] = True
        d = hop_distances(adj)
        if (d >= 0).all():
            return live, int(d.max())
    raise RuntimeError("could not draw a connected expander")


def expander_all_gather(
    x: jnp.ndarray, axis_name, u: int = 3, seed: int = 0
) -> jnp.ndarray:
    """All-gather a *small* tensor immediately over the live expander.

    Gossip over the union of u matchings for `diameter` rounds: round h
    forwards everything known so far to each of the u neighbors.  Total
    wire bytes per shard ~= u * diameter * N * |x| — the bandwidth tax the
    paper accepts for the (tiny) latency-sensitive fraction, in exchange
    for not waiting on the rotor cycle.  Use for control-plane tensors.
    """
    n = _axis_size(axis_name)
    i = lax.axis_index(axis_name)
    if n == 1:
        return x[None]
    live, diam = _expander_routing(n, min(u, n - 1), seed)
    buf = jnp.zeros((n,) + x.shape, x.dtype)
    buf = buf.at[i].set(x)
    mask = jnp.zeros((n,), bool).at[i].set(True)
    for _ in range(diam):
        for p in live:
            pairs = _perm_pairs(p)
            if not pairs:
                continue
            rbuf = lax.ppermute(buf, axis_name, pairs)
            rmask = lax.ppermute(mask, axis_name, pairs)
            take = rmask & ~mask
            buf = jnp.where(take[(...,) + (None,) * x.ndim], rbuf, buf)
            mask = mask | rmask
    return buf


def expander_psum_latency(x: jnp.ndarray, axis_name, u: int = 3) -> jnp.ndarray:
    """Latency-class sum of a small tensor (e.g. a loss scalar)."""
    return expander_all_gather(x, axis_name, u=u).sum(axis=0)


# --------------------------------------------------------------------------
# hierarchical schedules (multi-pod)
# --------------------------------------------------------------------------


def hierarchical_rotor_all_reduce(
    x: jnp.ndarray, data_axis, pod_axis=None
) -> jnp.ndarray:
    """RS(data) -> AR(pod, direct) -> AG(data).

    Inter-pod traffic is (N_pod - 1) direct exchanges of the 1/N_data
    shard — the pod axis never sees the full gradient, which is what lets
    the schedule scale to many pods (each added pod adds one matching
    slice, not one ring lap).
    """
    shape, size = x.shape, x.size
    chunk = rotor_reduce_scatter(x, data_axis)
    if pod_axis is not None:
        chunk = rotor_all_reduce(chunk, pod_axis, mode="direct")
    full = rotor_all_gather(chunk, data_axis).reshape(-1)
    return full[:size].reshape(shape)


def rotor_psum_tree(tree, data_axis, pod_axis=None):
    return jax.tree.map(
        lambda g: hierarchical_rotor_all_reduce(g, data_axis, pod_axis), tree
    )


# --------------------------------------------------------------------------
# gradient compression (beyond-paper distributed-optimization trick)
# --------------------------------------------------------------------------


def compressed_rotor_all_reduce(
    x: jnp.ndarray,
    axis_name,
    error: Optional[jnp.ndarray] = None,
    bits: int = 8,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8-quantized rotor all-reduce with error feedback.

    Quantize (x + carried_error) to int`bits` with a per-shard scale,
    all-reduce the quantized payload (4x fewer wire bytes at bits=8),
    and carry the quantization residual into the next step.
    Returns (all_reduced_approx, new_error).
    """
    if error is not None:
        x = x + error
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    deq = q.astype(x.dtype) * scale
    new_error = x - deq
    # sum of per-shard dequantized tensors (scales differ per shard, so
    # reduce in the dequantized domain; wire bytes are int8 + one scalar)
    payload = q.astype(jnp.bfloat16) * scale.astype(jnp.bfloat16)
    total = rotor_all_reduce(payload.astype(jnp.float32), axis_name)
    return total.astype(x.dtype), new_error


# --------------------------------------------------------------------------
# schedule metadata (for benchmarks / EXPERIMENTS.md)
# --------------------------------------------------------------------------


def schedule_stats(n: int, u: int = 3) -> dict:
    """Wire-byte accounting per shard for |x| = 1 unit, matching §2/§3."""
    live, diam = _expander_routing(n, min(u, max(n - 1, 1)))
    return dict(
        axis_size=n,
        slices=n,
        rotor_ar_bytes=2 * (n - 1) / n,           # RS+AG, per input byte
        rotor_ar_direct_bytes=(n - 1),            # small-N direct mode
        rotor_a2a_bytes=(n - 1) / n,              # per input byte
        rotor_a2a_vlb_bytes=2 * (n - 1) / n,      # 100 % VLB tax (§3.4)
        expander_diameter=diam,
        expander_allgather_bytes=float(len(live) * diam),  # per gathered byte
        bandwidth_tax_latency=float(max(diam - 1, 0)),
    )
