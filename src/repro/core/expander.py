"""Expander-graph diagnostics (Fig. 4, Appendix D).

Spectral gap, path-length distributions, and connectivity checks for the
time-varying slices of an Opera topology and for static comparison
networks.  Pure numpy; sizes here are O(100s) of racks so dense linear
algebra is fine.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.topology import OperaTopology


def degree(adj: np.ndarray) -> np.ndarray:
    return adj.sum(axis=1)


def spectral_gap(adj: np.ndarray) -> float:
    """Gap of the degree-normalized adjacency: 1 - max(|lambda_2|, |lambda_n|).

    Larger is better; a d-regular Ramanujan graph achieves
    1 - 2*sqrt(d-1)/d, the optimum (Appendix D / [25]).
    """
    d = degree(adj).astype(np.float64)
    if (d == 0).any():
        return 0.0
    # symmetric normalization D^-1/2 A D^-1/2
    dinv = 1.0 / np.sqrt(d)
    norm = adj * dinv[:, None] * dinv[None, :]
    ev = np.linalg.eigvalsh(norm)
    # ev[-1] == 1 (Perron); gap to the next-largest magnitude eigenvalue
    second = max(abs(ev[0]), abs(ev[-2]))
    return float(1.0 - second)


def ramanujan_bound(d: int) -> float:
    return float(1.0 - 2.0 * np.sqrt(max(d - 1, 0)) / max(d, 1))


def hop_distances(adj: np.ndarray, max_hops: int = 32) -> np.ndarray:
    """All-pairs hop counts by boolean matrix powers.  -1 = unreachable."""
    n = adj.shape[0]
    dist = np.full((n, n), -1, dtype=np.int64)
    np.fill_diagonal(dist, 0)
    reach = np.eye(n, dtype=bool)
    frontier_adj = adj | np.eye(n, dtype=bool)
    cur = np.eye(n, dtype=bool)
    for h in range(1, max_hops + 1):
        cur = cur @ frontier_adj
        newly = cur & ~reach
        if not newly.any():
            break
        dist[newly] = h
        reach |= newly
    return dist


def path_length_cdf(adj: np.ndarray) -> Dict[int, float]:
    """CDF over ToR-pair hop counts (off-diagonal, reachable pairs)."""
    d = hop_distances(adj)
    n = d.shape[0]
    off = d[~np.eye(n, dtype=bool)]
    off = off[off > 0]
    out: Dict[int, float] = {}
    if off.size == 0:
        return out
    for h in range(1, int(off.max()) + 1):
        out[h] = float((off <= h).mean())
    return out


def mean_max_path(adj: np.ndarray) -> Tuple[float, int, int]:
    """(mean hops, max hops, #disconnected ordered pairs)."""
    d = hop_distances(adj)
    n = d.shape[0]
    off = d[~np.eye(n, dtype=bool)]
    disc = int((off < 0).sum())
    fin = off[off > 0]
    if fin.size == 0:
        return float("inf"), 0, disc
    return float(fin.mean()), int(fin.max()), disc


def slice_report(topo: OperaTopology, slices: Sequence[int] | None = None):
    """Per-slice expander diagnostics (Appendix D reproduction)."""
    if slices is None:
        slices = range(topo.num_slices)
    rows = []
    for t in slices:
        adj = topo.adjacency(t)
        mean_h, max_h, disc = mean_max_path(adj)
        rows.append(
            dict(
                slice=int(t),
                live_degree=int(degree(adj).max()),
                spectral_gap=spectral_gap(adj),
                mean_path=mean_h,
                max_path=max_h,
                disconnected_pairs=disc,
            )
        )
    return rows


# ---------------- static comparison topologies ----------------------------


def random_regular_expander(
    num_nodes: int, u: int, seed: int = 0
) -> np.ndarray:
    """Static expander as the union of u random matchings (Jellyfish-style,
    the paper's u=7 comparison network)."""
    from repro.core.topology import random_matchings

    adj = np.zeros((num_nodes, num_nodes), dtype=bool)
    i = np.arange(num_nodes)
    ms = random_matchings(num_nodes, seed)
    # skip the identity-heavy matchings first if any; take u non-trivial ones
    taken = 0
    for p in ms:
        if taken == u:
            break
        mask = p != i
        if not mask.any():
            continue
        adj[i[mask], p[mask]] = True
        taken += 1
    return adj


def folded_clos_tor_hops(num_racks: int) -> Dict[int, float]:
    """ToR-to-ToR hop CDF for a 3-tier folded Clos: any two distinct ToRs
    are (logically) 'ToR-agg-ToR' = 2 ToR-to-ToR hops if under one agg
    block, else 4 via core.  We model the common 648-host k=12 build: 12
    pods of 9 ToRs.  (Used only for the Fig. 4 comparison plot.)"""
    pods = max(1, int(round(num_racks ** 0.5 / 1.0)) // 3 * 3) or 1
    racks_per_pod = max(1, num_racks // 12)
    same_pod_pairs = 0
    cross_pairs = 0
    for _ in range(12):
        same_pod_pairs += racks_per_pod * (racks_per_pod - 1)
    total = num_racks * (num_racks - 1)
    cross_pairs = total - same_pod_pairs
    return {
        2: same_pod_pairs / total,
        4: 1.0,
        "_mix": (same_pod_pairs / total, cross_pairs / total),
    }
