"""Opera core: topology generation, schedules, routing, rotor collectives."""
from repro.core.classify import Classifier, TrafficClass  # noqa: F401
from repro.core.topology import (  # noqa: F401
    OperaTopology,
    build_opera_topology,
    lift_matchings,
    random_matchings,
    rotor_schedule,
    sum_matchings,
    verify_factorization,
)
