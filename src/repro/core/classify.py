"""Traffic classification (§2.1, §3.4, §4.1).

Opera is agnostic to *how* traffic is classified; the default is a flow-size
threshold (flows that can amortize one cycle of waiting ride direct paths),
with application-based tagging as an override (e.g. shuffle flows are bulk
regardless of size).  The same notions drive the framework's collectives:
gradient/expert payloads are `BULK`, control-plane tensors are `LATENCY`.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Optional


class TrafficClass(enum.Enum):
    LATENCY = "latency"  # forwarded immediately over the expander (taxed)
    BULK = "bulk"        # buffered for the direct circuit (tax-free)


@dataclass(frozen=True)
class Classifier:
    bulk_cutoff_bytes: int = 15 * 2**20

    def classify(
        self, size_bytes: int, app_tag: Optional[TrafficClass] = None
    ) -> TrafficClass:
        if app_tag is not None:
            return app_tag
        return (
            TrafficClass.BULK
            if size_bytes >= self.bulk_cutoff_bytes
            else TrafficClass.LATENCY
        )


def bandwidth_tax(path_hops: int) -> float:
    """x bytes over k hops consume k*x of fabric capacity: tax = k-1."""
    return max(path_hops - 1, 0)


def effective_tax_rate(
    frac_bytes_indirect: float, avg_indirect_hops: float
) -> float:
    """Aggregate tax rate for a workload split between direct (1 hop,
    tax 0) and indirect traffic (§5.1: 4 % of bytes at L~3.1 -> 8.4 %)."""
    return frac_bytes_indirect * bandwidth_tax(avg_indirect_hops)
