"""Per-slice routing (§3.4, §3.6.2, §5.5).

For every topology slice we precompute next-hop tables over the union of
live matchings (the time-varying expander).  Failures (links, ToRs,
circuit switches) are masked out and routes recomputed — the paper's
hello-protocol reconvergence, evaluated in Fig. 11 / Appendix E.

Routing tables are design-time state of size O(N_racks^2) per slice
(Table 1); `ruleset_size()` reproduces the scalability table.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.topology import OperaTopology


@dataclasses.dataclass
class FailureSet:
    """Failed components.  Links are undirected rack pairs; uplinks are
    physical ``(rack, switch)`` fibers — the sampling unit of the fault
    subsystem (`netsim.faults`), where a dead fiber kills both
    directions of that rack's edge on every matching the switch serves.

    Membership is set-based, but anything that *iterates* in a
    result-affecting order must go through the ``sorted_*`` views so
    results never depend on set hashing.
    """

    links: Set[Tuple[int, int]] = dataclasses.field(default_factory=set)
    tors: Set[int] = dataclasses.field(default_factory=set)
    switches: Set[int] = dataclasses.field(default_factory=set)
    uplinks: Set[Tuple[int, int]] = dataclasses.field(default_factory=set)

    def link_failed(self, a: int, b: int) -> bool:
        return (min(a, b), max(a, b)) in self.links

    def uplink_failed(self, rack: int, switch: int) -> bool:
        return (rack, switch) in self.uplinks

    @property
    def sorted_links(self) -> List[Tuple[int, int]]:
        return sorted(self.links)

    @property
    def sorted_tors(self) -> List[int]:
        return sorted(self.tors)

    @property
    def sorted_switches(self) -> List[int]:
        return sorted(self.switches)

    @property
    def sorted_uplinks(self) -> List[Tuple[int, int]]:
        return sorted(self.uplinks)


def slice_adjacency(
    topo: OperaTopology, t: int, failures: Optional[FailureSet] = None
) -> np.ndarray:
    """Adjacency of slice t with failures applied."""
    n = topo.num_racks
    adj = np.zeros((n, n), dtype=bool)
    idx = np.arange(n)
    for s, p in topo.live_matchings(t):
        if failures and s in failures.switches:
            continue
        mask = p != idx
        if failures and failures.uplinks:
            dead = np.fromiter(
                ((int(r), s) in failures.uplinks for r in idx), bool, n
            )
            mask = mask & ~dead & ~dead[p]
        adj[idx[mask], p[mask]] = True
    if failures:
        for (a, b) in failures.sorted_links:
            adj[a, b] = adj[b, a] = False
        for tor in failures.sorted_tors:
            adj[tor, :] = False
            adj[:, tor] = False
    return adj


def bfs_next_hop(adj: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized multi-source BFS.

    Returns (dist, next_hop): dist[i,j] = hop count (-1 unreachable),
    next_hop[i,j] = neighbor of i on a shortest i->j path (-1 if none).
    """
    n = adj.shape[0]
    dist = np.full((n, n), -1, dtype=np.int64)
    np.fill_diagonal(dist, 0)
    nxt = np.full((n, n), -1, dtype=np.int64)
    # dist 1 = direct neighbors
    nbrs = [np.nonzero(adj[i])[0] for i in range(n)]
    reach = np.eye(n, dtype=bool)
    dist1 = adj & ~reach
    dist[dist1] = 1
    ii, jj = np.nonzero(dist1)
    nxt[ii, jj] = jj
    reach |= dist1
    frontier = dist1
    h = 1
    while frontier.any():
        h += 1
        # newly reachable: one more hop through any neighbor
        new = (frontier @ adj.T.astype(frontier.dtype)).astype(bool) & ~reach
        # orient as [src, dst]: node j newly reachable from i if some
        # neighbor k of i had dist[i->j] == h-1 ... do it per-source:
        newly_any = False
        for i in range(n):
            cand = ~reach[i]
            if not cand.any():
                continue
            # dsts reachable at h via neighbor k with dist[k, dst] == h-1
            ks = nbrs[i]
            if len(ks) == 0:
                continue
            sub = dist[ks][:, cand] == h - 1
            hit = sub.any(axis=0)
            if not hit.any():
                continue
            newly_any = True
            dst_idx = np.nonzero(cand)[0][hit]
            # pick the first qualifying neighbor (deterministic)
            kpick = ks[np.argmax(sub[:, hit], axis=0)]
            dist[i, dst_idx] = h
            nxt[i, dst_idx] = kpick
            reach[i, dst_idx] = True
        if not newly_any:
            break
        frontier = dist == h
    return dist, nxt


@dataclasses.dataclass
class SliceRoutes:
    slice_id: int
    dist: np.ndarray
    next_hop: np.ndarray

    @property
    def disconnected_pairs(self) -> int:
        n = self.dist.shape[0]
        off = self.dist[~np.eye(n, dtype=bool)]
        return int((off < 0).sum())


def compute_routes(
    topo: OperaTopology,
    failures: Optional[FailureSet] = None,
    slices: Optional[Sequence[int]] = None,
) -> List[SliceRoutes]:
    out = []
    for t in slices if slices is not None else range(topo.num_slices):
        adj = slice_adjacency(topo, t, failures)
        if failures:
            # failed ToRs are not sources/destinations of interest
            pass
        dist, nxt = bfs_next_hop(adj)
        out.append(SliceRoutes(int(t), dist, nxt))
    return out


def connectivity_loss(
    topo: OperaTopology,
    failures: FailureSet,
    slices: Optional[Sequence[int]] = None,
) -> Dict[str, float]:
    """Fig. 11 metrics: worst-slice and integrated-across-slices fraction
    of disconnected (non-failed) ToR pairs."""
    n = topo.num_racks
    alive = np.array([i for i in range(n) if i not in failures.tors])
    na = len(alive)
    total_pairs = na * (na - 1)
    worst = 0
    union_ok = np.zeros((n, n), dtype=bool)  # pair connected in >= 1 slice
    every_ok = None
    for t in slices if slices is not None else range(topo.num_slices):
        adj = slice_adjacency(topo, t, failures)
        from repro.core.expander import hop_distances

        dist = hop_distances(adj)
        sub = dist[np.ix_(alive, alive)]
        ok = sub >= 0
        np.fill_diagonal(ok, True)
        worst = max(worst, int((~ok).sum()))
        full = np.zeros((n, n), dtype=bool)
        full[np.ix_(alive, alive)] = ok
        union_ok |= full
        every_ok = full if every_ok is None else (every_ok & full)
    ever_disc = total_pairs - int(
        union_ok[np.ix_(alive, alive)].sum() - na
    )  # minus diagonal
    return dict(
        worst_slice_disconnected_frac=worst / max(total_pairs, 1),
        any_slice_disconnected_frac=ever_disc / max(total_pairs, 1),
        always_connected_frac=(
            (int(every_ok[np.ix_(alive, alive)].sum()) - na) / max(total_pairs, 1)
            if every_ok is not None
            else 1.0
        ),
    )


def path_stretch(
    topo: OperaTopology, failures: FailureSet, slices: Sequence[int]
) -> Dict[str, float]:
    """Appendix E: average / max finite path length under failures."""
    means, maxes = [], []
    for t in slices:
        adj = slice_adjacency(topo, t, failures)
        from repro.core.expander import mean_max_path

        m, mx, _ = mean_max_path(adj)
        if np.isfinite(m):
            means.append(m)
            maxes.append(mx)
    return dict(
        mean_path=float(np.mean(means)) if means else float("inf"),
        max_path=int(max(maxes)) if maxes else -1,
    )


def ruleset_size(num_racks: int, uplinks: Optional[int] = None) -> int:
    """Table 1: per-ToR forwarding entries.

    N_slices x (N-1) low-latency next-hop rules (one per destination per
    slice) plus N x u bulk rules (which uplink gives the direct circuit,
    per slice).  The published counts back out u = {6, 8, 12, 15, 17, 19}
    for N = {108..1200}, i.e. u ~ N/64 + 4 — the deployment's ToR radix
    growing with scale.  Model matches Table 1 within ~0.5 %.
    """
    u = uplinks if uplinks is not None else int(round(num_racks / 64)) + 4
    return num_racks * (num_racks - 1) + num_racks * u
