"""Opera topology generation (§3.3 of the paper).

A complete graph over N racks (the N x N all-ones matrix, self-loops
included) is factored into N disjoint symmetric matchings; matchings are
randomly assigned to the u circuit switches (N/u each) with a random
cycling order per switch; reconfigurations are staggered so that at any
slice exactly `groups` switches are dark and the union of the remaining
live matchings is an expander.

All of this is *design-time* computation: no topology math happens while
the network (or the collective schedule derived from it) is running —
exactly as in the paper.

Matchings are represented as integer partner vectors `p` of length N with
``p[p[i]] == i`` (involution); ``p[i] == i`` marks a self-loop (rack i has
no circuit in this matching — it keeps the byte, zero cost).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

Matching = np.ndarray  # int64[N], involution


# --------------------------------------------------------------------------
# Complete-graph factorization
# --------------------------------------------------------------------------


def sum_matchings(n: int) -> List[Matching]:
    """Factor K_n (with self-loops) into n disjoint symmetric matchings.

    Matching m pairs i with (m - i) mod n.  Over m = 0..n-1 every ordered
    pair (i, j) appears exactly once (i + j == m has one solution in m),
    so the union is the all-ones matrix.  Each matching is an involution:
    partner(partner(i)) = m - (m - i) = i.
    """
    i = np.arange(n)
    return [((m - i) % n).astype(np.int64) for m in range(n)]


def conjugate(matchings: Sequence[Matching], perm: np.ndarray) -> List[Matching]:
    """Relabel racks by `perm` (the paper's *random* factorization).

    If p is an involution then pi . p . pi^-1 is one too, and disjointness
    / coverage are preserved.
    """
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    return [perm[p[inv]] for p in matchings]


def _random_perfect_matching(
    avail: np.ndarray, rng: np.random.Generator
) -> Optional[Matching]:
    """Random perfect matching on the graph `avail` (greedy w/ retries,
    exact blossom fallback for the sparse tail)."""
    n = avail.shape[0]
    for _ in range(30):
        p = np.full(n, -1, dtype=np.int64)
        ok = True
        for v in rng.permutation(n):
            if p[v] >= 0:
                continue
            cands = np.nonzero(avail[v] & (p < 0))[0]
            cands = cands[cands != v]
            if len(cands) == 0:
                ok = False
                break
            u = int(rng.choice(cands))
            p[v], p[u] = u, v
        if ok:
            return p
    # exact fallback (remaining graph sparse): Edmonds blossom
    import networkx as nx

    g = nx.Graph()
    g.add_nodes_from(range(n))
    ii, jj = np.nonzero(np.triu(avail, 1))
    g.add_edges_from(zip(ii.tolist(), jj.tolist()))
    m = nx.max_weight_matching(g, maxcardinality=True)
    if len(m) * 2 != n:
        return None
    p = np.full(n, -1, dtype=np.int64)
    for a, b in m:
        p[a], p[b] = b, a
    return p


def random_matchings(n: int, seed: int = 0) -> List[Matching]:
    """RANDOM factorization of the all-ones matrix (§3.3): n-1 random
    disjoint perfect matchings of K_n plus the identity (self-loop slice).

    The conjugated circle-method factorization is NOT used here — its
    matching unions are circulant-structured with poor expansion (mean
    path ~8 at n=130 vs ~2.5 for a random union).  Requires even n; odd n
    falls back to the structured factorization (unused by our designs).
    """
    if n % 2:
        rng = np.random.default_rng(seed)
        return conjugate(sum_matchings(n), rng.permutation(n))
    for attempt in range(20):
        rng = np.random.default_rng(seed * 1009 + attempt)
        avail = ~np.eye(n, dtype=bool)
        out: List[Matching] = []
        failed = False
        for _ in range(n - 1):
            p = _random_perfect_matching(avail, rng)
            if p is None:
                failed = True
                break
            avail[np.arange(n), p] = False
            avail[p, np.arange(n)] = False
            out.append(p)
        if failed:
            continue
        spread = _spread_diagonal(out, rng)
        if spread is not None:
            return spread
        # tiny n (e.g. 4) cannot spread the diagonal: any two perfect
        # matchings' union is a single cycle — keep an identity slice.
        out.append(np.arange(n, dtype=np.int64))
        return out
    raise RuntimeError(f"could not factor K_{n} randomly")


def _spread_diagonal(
    perfect: List[Matching], rng: np.random.Generator
) -> Optional[List[Matching]]:
    """Turn n-1 perfect matchings of K_n into n matchings covering the
    all-ones matrix with the diagonal SPREAD across them.

    A degenerate identity slice (every rack idle) would drop a whole
    switch-dwell of capacity and can disconnect small-u topologies; instead
    we remove one edge from each of n/2 distinct matchings — the removed
    edges chosen to form a perfect matching themselves (they become the
    n-th matching) — leaving 2 self-loops in each donor matching.
    """
    n = len(perfect[0])
    k = n // 2
    idx = list(range(len(perfect)))
    for _ in range(200):
        rng.shuffle(idx)
        donors = idx[:k]
        covered = np.zeros(n, dtype=bool)
        chosen = []
        ok = True
        for j in donors:
            p = perfect[j]
            free = np.nonzero(~covered & ~covered[p])[0]
            free = free[free < p[free]]  # canonical edge orientation
            if len(free) == 0:
                ok = False
                break
            a = int(rng.choice(free))
            b = int(p[a])
            covered[a] = covered[b] = True
            chosen.append((j, a, b))
        if not ok or not covered.all():
            continue
        out = [m.copy() for m in perfect]
        new = np.arange(n, dtype=np.int64)
        for j, a, b in chosen:
            out[j][a] = a   # donor keeps self-loops at a, b
            out[j][b] = b
            new[a], new[b] = b, a
        out.append(new)
        return out
    return None


def lift_matchings(base: Sequence[Matching], factor: int) -> List[Matching]:
    """Graph lifting (§3.3): grow a factorization of K_n to one of K_{n*f}.

    Vertex (v, c) -> index v*f + c.  Base matching m and lift phase g pair
    (v, c) with (partner_m(v), (g - c) mod f).  Involution and exact
    coverage follow from the base properties plus the sum-factorization of
    the copy index.  Produces n*f matchings for n*f vertices from only n
    base matchings — this is how large Opera instances are generated
    without factoring a large complete graph.
    """
    f = factor
    out: List[Matching] = []
    c = np.arange(f)
    for p in base:
        for g in range(f):
            lifted = np.empty(len(p) * f, dtype=np.int64)
            for v in range(len(p)):
                lifted[v * f + c] = p[v] * f + ((g - c) % f)
            out.append(lifted)
    return out


def verify_factorization(matchings: Sequence[Matching]) -> None:
    """Disjoint symmetric matchings covering the all-ones matrix."""
    n = len(matchings[0])
    if len(matchings) != n:
        raise ValueError(f"need n={n} matchings, got {len(matchings)}")
    cover = np.zeros((n, n), dtype=np.int64)
    for p in matchings:
        if not np.array_equal(p[p], np.arange(n)):
            raise ValueError("matching is not an involution")
        cover[np.arange(n), p] += 1
    if not (cover == 1).all():
        raise ValueError("matchings do not exactly factor the complete graph")


# --------------------------------------------------------------------------
# Switch assignment + slice schedule
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OperaTopology:
    """A fully-instantiated Opera design point.

    switch_matchings[s][j] is the j-th matching in switch s's cycle.
    One cycle = num_slices slices; during slice t the switches in
    `dark_switches(t)` are reconfiguring (their uplinks carry no traffic).
    """

    num_racks: int
    num_switches: int              # u
    switch_matchings: Tuple[Tuple[Matching, ...], ...]
    groups: int = 1                # switches reconfiguring simultaneously

    # -------------- schedule geometry ------------------------------------
    @property
    def u(self) -> int:
        return self.num_switches

    @property
    def matchings_per_switch(self) -> int:
        return len(self.switch_matchings[0])

    @property
    def num_slices(self) -> int:
        # Each switch reconfigures matchings_per_switch times per cycle and
        # (num_switches/groups) switch-groups take turns -> the cycle has
        # matchings_per_switch * u / groups slices.
        return self.matchings_per_switch * self.num_switches // self.groups

    def dark_switches(self, t: int) -> Tuple[int, ...]:
        """Switches reconfiguring during slice t (staggered, Fig. 3b)."""
        t = t % self.num_slices
        rounds = self.num_switches // self.groups
        g = t % rounds
        return tuple(g * self.groups + i for i in range(self.groups))

    def matching_index(self, s: int, t: int) -> int:
        """Which of switch s's matchings is installed during slice t."""
        t = t % self.num_slices
        rounds = self.num_switches // self.groups
        # switch s last reconfigured at the most recent slice t' <= t with
        # t' % rounds == s // groups; it has reconfigured floor over cycle.
        phase = s // self.groups
        n_reconf = (t - phase) // rounds + 1 if t >= phase else 0
        return n_reconf % self.matchings_per_switch

    def live_matchings(self, t: int) -> List[Tuple[int, Matching]]:
        """(switch, matching) pairs carrying traffic during slice t."""
        dark = set(self.dark_switches(t))
        return [
            (s, self.switch_matchings[s][self.matching_index(s, t)])
            for s in range(self.num_switches)
            if s not in dark
        ]

    def all_matchings_for_switch(self, s: int) -> Tuple[Matching, ...]:
        return self.switch_matchings[s]

    def adjacency(self, t: int) -> np.ndarray:
        """Boolean rack-to-rack adjacency of slice t (self-loops dropped)."""
        n = self.num_racks
        adj = np.zeros((n, n), dtype=bool)
        i = np.arange(n)
        for _, p in self.live_matchings(t):
            mask = p != i
            adj[i[mask], p[mask]] = True
        return adj

    def matching_tensor(self) -> np.ndarray:
        """Dense export of the whole cycle for array engines.

        Returns a ``(num_slices, N, N)`` float32 tensor whose slice ``t``
        is the live rack-to-rack adjacency (1.0 where racks i-j hold a
        direct circuit during slice t, self-loops dropped).  Because the
        factorization is exact, each off-diagonal pair is live on exactly
        one slice per cycle.  This is the design-time artifact the
        batched JAX fluid engine (netsim/fluid_jax.py) scans over — no
        topology math happens inside the simulation loop.
        """
        return np.stack(
            [self.adjacency(t) for t in range(self.num_slices)]
        ).astype(np.float32)

    def matching_index_tensor(self) -> np.ndarray:
        """Permutation-sparse export of the whole cycle for array engines.

        Returns a ``(num_slices, N, u)`` int32 tensor: entry ``[t, i, s]``
        is the rack that switch s connects rack i to during slice t, or
        the sentinel ``N`` when the slot is dark — switch s reconfiguring
        during slice t (grouped reconfiguration darkens `groups` columns
        per slice) or the matching holding a self-loop at i.  Because
        every live matching is an involution, ``dst[dst[i, s], s] == i``
        for every non-sentinel entry, and scattering ones along
        ``(i, dst[i, s])`` reconstructs `matching_tensor()` exactly.
        This is the design-time artifact the sparse engine
        (netsim/fluid_jax.py + kernels/rotor_slice) gathers over — it is
        u/N times the dense tensor's footprint, which is what makes the
        k >= 32 Appendix-B points tractable.
        """
        n, u = self.num_racks, self.num_switches
        out = np.full((self.num_slices, n, u), n, dtype=np.int32)
        i = np.arange(n)
        for t in range(self.num_slices):
            for s, p in self.live_matchings(t):
                live = p != i
                out[t, i[live], s] = p[live]
        return out

    def direct_slice(self) -> np.ndarray:
        """direct[i, j] = first slice in which i-j have a direct circuit.

        Every rack pair must appear exactly once per cycle (the bulk-path
        guarantee).  Self-pairs get slice -1.
        """
        n = self.num_racks
        out = np.full((n, n), -1, dtype=np.int64)
        i = np.arange(n)
        for t in range(self.num_slices):
            for _, p in self.live_matchings(t):
                mask = (p != i) & (out[i, p] < 0)
                out[i[mask], p[mask]] = t
        return out


def build_opera_topology(
    num_racks: int,
    num_switches: int,
    seed: int = 0,
    groups: int = 1,
    base_matchings: Optional[Sequence[Matching]] = None,
    verify_slices: bool = True,
    switch_fault_tolerance: int = 0,
) -> OperaTopology:
    """Design-time construction with the paper's generate-and-test loop
    (§3.3): redraw until every topology slice is a connected expander —
    and, with switch_fault_tolerance=k, until connectivity survives any k
    circuit-switch failures in every slice (the Fig. 11c property; this is
    a property of the *realization*, so it is selected for at design time
    exactly as the paper prescribes)."""
    if num_racks % num_switches != 0:
        raise ValueError("num_racks must be divisible by num_switches (N/u whole)")
    if num_switches % groups != 0:
        raise ValueError("groups must divide num_switches")
    last = None
    for attempt in range(24):
        rng = np.random.default_rng(seed + 7919 * attempt)
        matchings = (
            list(base_matchings)
            if base_matchings is not None
            else random_matchings(num_racks, seed + 7919 * attempt)
        )
        verify_factorization(matchings)
        order = rng.permutation(num_racks)
        per = num_racks // num_switches
        switch_matchings = []
        for s in range(num_switches):
            idx = order[s * per : (s + 1) * per]
            cyc = [matchings[j] for j in idx]
            rng.shuffle(cyc)
            switch_matchings.append(tuple(cyc))
        topo = OperaTopology(
            num_racks=num_racks,
            num_switches=num_switches,
            switch_matchings=tuple(switch_matchings),
            groups=groups,
        )
        last = topo
        if not verify_slices or _slices_robust(topo, switch_fault_tolerance):
            return topo
    return last  # best effort (tests check connectivity explicitly)


def build_lifted_opera_topology(
    num_racks: int,
    num_switches: int,
    seed: int = 0,
    groups: int = 1,
    max_base: int = 128,
    verify_slices: bool = False,
) -> OperaTopology:
    """Large Appendix-B design points via graph lifting (§3.3).

    Factoring K_N directly is quadratic-with-a-big-constant in N; the
    paper grows big instances by lifting a small base factorization
    instead.  Picks the smallest lift factor f dividing num_racks whose
    base num_racks/f is even, >= 2*num_switches, and <= max_base (the
    largest base that is still cheap to factor), then lifts
    `random_matchings(base)`.  Slice verification defaults off: the
    generate-and-test loop rebuilds the (large) slice set per attempt,
    and the invariant layer (`repro.staticcheck`) is the place big
    points get audited.
    """
    base_n = num_racks
    factor = 1
    if num_racks > max_base:
        for f in range(2, num_racks // max(2 * num_switches, 2) + 1):
            if num_racks % f:
                continue
            b = num_racks // f
            if b % 2 == 0 and b >= 2 * num_switches and b <= max_base:
                base_n, factor = b, f
                break
        else:
            raise ValueError(
                f"no lift base for N={num_racks}, u={num_switches} "
                f"with max_base={max_base}")
    base = random_matchings(base_n, seed)
    matchings = lift_matchings(base, factor) if factor > 1 else base
    return build_opera_topology(
        num_racks, num_switches, seed=seed, groups=groups,
        base_matchings=matchings, verify_slices=verify_slices,
    )


def _connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    a = adj | np.eye(n, dtype=bool)
    reach = np.zeros(n, dtype=bool)
    reach[0] = True
    while True:
        new = a[reach].any(axis=0) & ~reach
        if not new.any():
            break
        reach |= new
    return bool(reach.all())


def _slices_robust(topo: OperaTopology, fault_tolerance: int) -> bool:
    import itertools

    n = topo.num_racks
    idx = np.arange(n)
    fail_sets = [frozenset()]
    if fault_tolerance:
        fail_sets += [
            frozenset(c)
            for k in range(1, fault_tolerance + 1)
            for c in itertools.combinations(range(topo.num_switches), k)
        ]
    for t in range(topo.num_slices):
        live = topo.live_matchings(t)
        for fs in fail_sets:
            adj = np.zeros((n, n), dtype=bool)
            for s, p in live:
                if s in fs:
                    continue
                mask = p != idx
                adj[idx[mask], p[mask]] = True
            if not _connected(adj):
                return False
    return True


# --------------------------------------------------------------------------
# Collective-schedule view (the TPU adaptation).
#
# For an N-way mesh axis the rotor schedule is the N-matching factorization
# itself: during "slice" m every shard i exchanges exactly with
# (m - i) mod N.  A rotor collective walks slices 1..N-1 (slice pairing a
# shard with itself moves no bytes), sending each peer's chunk on the one
# slice with a direct circuit -> every byte travels exactly one hop: the
# bulk class of the paper, zero bandwidth tax.
# --------------------------------------------------------------------------


def rotor_schedule(n: int) -> List[List[Tuple[int, int]]]:
    """ppermute perm lists for slices m = 1..n-1 of the sum factorization.

    Each perm list contains ordered (src, dst) pairs for every shard with a
    partner != itself.  Because matchings are involutions the perm is its
    own inverse — a bidirectional exchange.
    """
    perms: List[List[Tuple[int, int]]] = []
    for m in list(range(1, n)) + [0]:
        p = [(i, (m - i) % n) for i in range(n) if (m - i) % n != i]
        if p:
            perms.append(p)
    return perms


def expander_union(n: int, degree: int, seed: int = 0) -> np.ndarray:
    """Union of `degree` random matchings over n nodes (the 'live now'
    graph a latency-class message can use immediately)."""
    ms = random_matchings(n, seed)[:degree]
    adj = np.zeros((n, n), dtype=bool)
    i = np.arange(n)
    for p in ms:
        mask = p != i
        adj[i[mask], p[mask]] = True
    return adj
