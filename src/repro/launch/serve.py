"""Serving launcher: continuous-batching engine over a (reduced) arch.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --requests 8 --slots 4 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced_config
from repro.models import init_params
from repro.models.parallel import single_device_ctx
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    params = init_params(cfg, jax.random.key(args.seed))
    eng = ServeEngine(cfg, params, single_device_ctx(), slots=args.slots,
                      max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for rid in range(args.requests):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(
                0, cfg.vocab_size, int(rng.integers(4, 16))
            ).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    done = eng.run_to_completion()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {cfg.name}: {len(done)} requests, {toks} tokens, "
          f"{dt:.1f}s ({toks/dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.rid}: {list(r.prompt[:6])}... -> {r.out_tokens}")


if __name__ == "__main__":
    main()
