import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below this line may import jax ------------------------------
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis.hlo import collective_breakdown_table, collective_bytes  # noqa: E402
from repro.compat import set_mesh  # noqa: E402
from repro.analysis.hlo_cost import analyze as hlo_analyze  # noqa: E402
from repro.analysis.roofline import RooflineTerms, model_flops  # noqa: E402
from repro.configs import SHAPES, get_config, input_specs, list_archs  # noqa: E402
from repro.launch.mesh import make_production_mesh, pctx_for_mesh  # noqa: E402
from repro.models import model as MDL  # noqa: E402
from repro.models.kvcache import cache_specs  # noqa: E402
from repro.models.sharding import (  # noqa: E402
    batch_shardings,
    cache_shardings,
    param_shardings,
)
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.train.trainer import make_train_step  # noqa: E402

"""Multi-pod dry-run: .lower().compile() for every (arch x shape x mesh).

Produces one JSON per cell under --out with:
  - memory_analysis (per-device argument/output/temp/code bytes)
  - cost_analysis (per-device HLO FLOPs / bytes accessed)
  - per-kind collective wire bytes parsed from the compiled SPMD module
  - the derived roofline terms (analysis/roofline.py)

This is the proof that the distribution config is coherent: a sharding
mismatch, an unsupported collective, or a size blow-up fails the compile.
"""


def _specced(tree_shapes, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_shapes,
        shardings,
    )


def build_cell(arch: str, shape_name: str, mesh, pctx, opt_steps=10_000,
               cfg_overrides=None):
    cfg = get_config(arch)
    cfg = cfg.replace(
        grad_sync=pctx.grad_sync, moe_dispatch=pctx.moe_dispatch,
        **(cfg_overrides or {}),
    )
    spec = SHAPES[shape_name]
    pshapes = MDL.param_shapes(cfg)
    pshard = param_shardings(pshapes, cfg, pctx)
    params_sds = _specced(pshapes, pshard)
    batch_sds = batch_shardings(input_specs(cfg, spec), pctx)

    if spec.kind == "train":
        opt = AdamWConfig(total_steps=opt_steps)
        step_fn = make_train_step(cfg, pctx, opt)
        mzero = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, np.float32), pshapes
        )
        mshard = param_shardings(mzero, cfg, pctx)
        m_sds = _specced(mzero, mshard)
        state_sds = {
            "params": params_sds,
            "opt": {
                "m": m_sds,
                "v": m_sds,
                "step": jax.ShapeDtypeStruct(
                    (), np.int32, sharding=NamedSharding(mesh, P())
                ),
            },
        }
        return cfg, spec, jax.jit(step_fn), (state_sds, batch_sds)

    if spec.kind == "prefill":
        fn = lambda p, b: MDL.forward_prefill(p, b, cfg, pctx)  # noqa: E731
        return cfg, spec, jax.jit(fn), (params_sds, batch_sds)

    # decode
    B = spec.global_batch
    cspecs = cache_specs(cfg, B, spec.seq_len)
    cache_sds = cache_shardings(cspecs, pctx)
    tok = jax.ShapeDtypeStruct(
        (B, 1), np.int32, sharding=NamedSharding(mesh, P())
    )
    pos = jax.ShapeDtypeStruct(
        (B,), np.int32, sharding=NamedSharding(mesh, P())
    )
    fn = lambda p, t, q, c: MDL.forward_decode(p, t, q, c, cfg, pctx)  # noqa: E731
    return cfg, spec, jax.jit(fn), (params_sds, tok, pos, cache_sds)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             overrides=None, tag: str = "") -> dict:
    multi = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi)
    chips = int(np.prod(list(mesh.shape.values())))
    cfg0 = get_config(arch)
    kw = dict(grad_sync=cfg0.grad_sync, moe_dispatch=cfg0.moe_dispatch)
    cfg_over = {}
    for key, val in (overrides or {}).items():
        if key in ("loss_chunk_vocab", "remat", "param_dtype", "norm_upcast"):
            if key == "loss_chunk_vocab":
                cfg_over[key] = int(val)
            elif key == "norm_upcast":
                cfg_over[key] = val not in ("0", "false", "False")
            else:
                cfg_over[key] = val
        else:
            kw[key] = val
    pctx = pctx_for_mesh(mesh, **kw)

    t0 = time.time()
    cfg, spec, jfn, args = build_cell(arch, shape_name, mesh, pctx,
                                      cfg_overrides=cfg_over)
    with set_mesh(mesh):
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem_d[f] = int(getattr(mem, f, 0) or 0)
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # loop-aware costs (XLA's cost_analysis counts while bodies once; our
    # stacks are scans, so trip-count-corrected numbers are the real ones)
    la = hlo_analyze(hlo)
    flops = float(la["flops"])
    bytes_acc = float(la["bytes"])
    coll = collective_bytes(hlo)  # naive (loop bodies once) — kept for ref
    coll_total = float(la.get("coll_bytes_total", 0.0))

    n_params = MDL.count_params(cfg)
    n_active = MDL.count_params(cfg, active_only=True)
    terms = RooflineTerms(
        arch=arch,
        shape=shape_name,
        mesh=mesh_kind,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=bytes_acc,
        coll_bytes_per_device=coll_total,
        model_flops_total=model_flops(cfg, spec, n_params, n_active),
    )
    rec = dict(
        arch=arch,
        shape=shape_name,
        mesh=mesh_kind,
        chips=chips,
        status="ok",
        params=n_params,
        active_params=n_active,
        seconds_lower=t_lower,
        seconds_compile=t_compile,
        memory_analysis=mem_d,
        cost_flops=flops,
        cost_bytes=bytes_acc,
        xla_cost_analysis={k: float(cost.get(k, 0.0))
                           for k in ("flops", "bytes accessed")},
        loop_aware=la,
        collectives=coll,
        roofline=terms.row(),
        overrides=overrides or {},
    )
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = out_dir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    fname.write_text(json.dumps(rec, indent=1))
    print(
        f"[dryrun] {arch} x {shape_name} x {mesh_kind}{suffix}: OK "
        f"(lower {t_lower:.1f}s compile {t_compile:.1f}s) "
        f"flops/dev={flops:.3e} bytes/dev={bytes_acc:.3e} "
        f"coll/dev={coll_total:.3e} "
        f"dominant={terms.dominant} useful={terms.useful_flops_ratio:.2f}",
        flush=True,
    )
    if mem_d:
        tot = (mem_d.get("argument_size_in_bytes", 0)
               + mem_d.get("output_size_in_bytes", 0)
               + mem_d.get("temp_size_in_bytes", 0)
               - mem_d.get("alias_size_in_bytes", 0))
        print(f"memory_analysis: {mem_d} -> {tot/2**30:.2f} GiB/device", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--grad-sync", dest="grad_sync", default=None)
    ap.add_argument("--moe-dispatch", dest="moe_dispatch", default=None)
    ap.add_argument("--act-sharding", dest="act_sharding", default=None)
    ap.add_argument("--layout", default=None)
    ap.add_argument("--loss-chunk", dest="loss_chunk_vocab", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--param-dtype", dest="param_dtype", default=None)
    ap.add_argument("--norm-upcast", dest="norm_upcast", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    out_dir = Path(args.out)
    overrides = {}
    for k in ("grad_sync", "moe_dispatch", "act_sharding", "layout",
              "loss_chunk_vocab", "remat", "param_dtype", "norm_upcast"):
        v = getattr(args, k)
        if v:
            overrides[k] = v

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = (
            list(cfg.shapes) if args.shape == "all" else [args.shape]
        )
        for shape in shapes:
            if shape not in cfg.shapes:
                print(f"[dryrun] {arch} x {shape}: SKIP "
                      f"({cfg.skipped_shapes.get(shape, 'not in shape set')})")
                continue
            for mk in meshes:
                sfx = f"__{args.tag}" if args.tag else ""
                f = out_dir / f"{arch}__{shape}__{mk}{sfx}.json"
                if args.skip_existing and f.exists():
                    print(f"[dryrun] {arch} x {shape} x {mk}: cached")
                    continue
                try:
                    run_cell(arch, shape, mk, out_dir,
                             overrides or None, tag=args.tag)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mk, repr(e)))
                    print(f"[dryrun] {arch} x {shape} x {mk}: FAIL {e!r}",
                          flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUN CELLS PASSED")


if __name__ == "__main__":
    main()
