"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (TPU v5e pod),
axes (data, model).  Multi-pod: 2 pods x 256 = 512 chips, axes
(pod, data, model); the `pod` axis is the rotor-scheduled inter-pod
dimension (DESIGN.md §3.1).

Generic mesh construction lives in ``repro.compat.make_mesh`` — import
it from there (the SC-AST-SHADOW staticcheck rule rejects re-exports of
the compat surface; this module used to carry a trivial `make_mesh`
alias that shadowed it).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many (fake or real) local devices exist —
    used by tests and the CPU examples, never by the dry-run."""
    n = len(jax.devices())
    data = n // model
    return _compat_make_mesh((data, model), ("data", "model"))


def pctx_for_mesh(mesh, **kw):
    from repro.models.parallel import ParallelContext

    axes = mesh.axis_names
    dp = ("pod", "data") if "pod" in axes else ("data",)
    if kw.get("layout") == "dp_only":
        dp = dp + ("model",)
    if "pod" in axes:
        return ParallelContext(
            mesh=mesh, dp_axes=dp, tp_axis="model", pod_axis="pod", **kw
        )
    return ParallelContext(mesh=mesh, dp_axes=dp, tp_axis="model", **kw)
