"""Training launcher.

CPU-scale smoke:  PYTHONPATH=src python -m repro.launch.train \
    --arch smollm-360m --reduced --steps 50 --batch 8 --seq 64

On hardware the same entry point takes --mesh pod/multipod and the full
configs; here the examples use --reduced with a host mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import set_mesh
from repro.configs import get_config
from repro.configs.base import reduced_config
from repro.data.pipeline import SyntheticLM, device_batches
from repro.launch.mesh import make_host_mesh, make_production_mesh, pctx_for_mesh
from repro.models import init_params
from repro.models.sharding import batch_spec, param_shardings
from repro.models.model import param_shapes
from repro.optim.adamw import AdamWConfig
from repro.train.checkpoint import Checkpointer
from repro.train.opera_dp import init_opera_dp_state, make_opera_dp_train_step
from repro.train.trainer import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="host", choices=["host", "pod", "multipod"])
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--trainer", default="opera-dp",
                    choices=["opera-dp", "gspmd"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if args.mesh == "host":
        mesh = make_host_mesh(model=args.tp)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")
    pctx = pctx_for_mesh(mesh)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 5))

    key = jax.random.key(args.seed)
    params = init_params(cfg, key)
    if args.trainer == "opera-dp":
        state = init_opera_dp_state(params, compress=args.compress_grads)
        step_fn = make_opera_dp_train_step(
            cfg, pctx, opt, compress=args.compress_grads
        )
    else:
        state = init_train_state(cfg, params)
        step_fn = make_train_step(cfg, pctx, opt)
        shardings = param_shardings(param_shapes(cfg), cfg, pctx)
        state = {
            "params": jax.device_put(state["params"], shardings),
            "opt": {
                "m": jax.device_put(state["opt"]["m"], shardings),
                "v": jax.device_put(state["opt"]["v"], shardings),
                "step": state["opt"]["step"],
            },
        }
    jitted = jax.jit(step_fn)

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        state, start_step = ckpt.restore(state)
        print(f"[train] resumed from step {start_step}")

    src = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    bspec = {
        k: NamedSharding(mesh, batch_spec(k, (args.batch, args.seq), pctx))
        for k in ("tokens", "targets")
    }
    batches = device_batches(src, start_step, bspec)

    print(f"[train] {cfg.name} ({sum(x.size for x in jax.tree.leaves(params)):,}"
          f" params), mesh {dict(mesh.shape)}, trainer={args.trainer}, "
          f"floor={src.conditional_entropy():.3f} nats")
    t0 = time.time()
    losses = []
    with set_mesh(mesh):
        for step in range(start_step, args.steps):
            batch = next(batches)
            state, metrics = jitted(state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(
                    f"[train] step {step:5d} loss {losses[-1]:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} "
                    f"lr {float(metrics['lr']):.2e} "
                    f"({(time.time() - t0):.1f}s)",
                    flush=True,
                )
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state)
    if ckpt:
        ckpt.save(args.steps, state, blocking=True)
    print(f"[train] done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(floor {src.conditional_entropy():.3f})")
    return losses


if __name__ == "__main__":
    main()
