"""Deterministic, resumable synthetic data pipeline.

Batches are a pure function of (seed, step): restart/elastic-resume needs
no dataloader state beyond the step counter (checkpoint.py records it).
The stream is a fixed random first-order Markov chain over the vocab, so
training measurably learns (loss drops from ln V toward the chain's
conditional entropy) — used by the e2e example and the trainer tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4          # out-degree of the Markov chain

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # each token has `branching` likely successors
        self.succ = rng.integers(
            0, self.vocab_size, (self.vocab_size, self.branching)
        )

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed + 1) * 1_000_003 + step)
        B, S = self.global_batch, self.seq_len
        toks = np.empty((B, S + 1), dtype=np.int64)
        toks[:, 0] = rng.integers(0, self.vocab_size, B)
        choices = rng.integers(0, self.branching, (B, S))
        noise = rng.random((B, S)) < 0.05
        rand_tok = rng.integers(0, self.vocab_size, (B, S))
        for t in range(S):
            nxt = self.succ[toks[:, t], choices[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
        }

    def conditional_entropy(self) -> float:
        """Entropy of the next-token distribution (nats) — the loss floor."""
        p_succ = 0.95 / self.branching
        h = -self.branching * p_succ * np.log(p_succ)
        h += -0.05 * np.log(0.05 / self.vocab_size)
        return float(h)


def device_batches(
    source: SyntheticLM,
    start_step: int,
    shardings: Optional[Dict] = None,
) -> Iterator[Dict[str, jnp.ndarray]]:
    step = start_step
    while True:
        host = source.batch_at(step)
        if shardings is None:
            yield {k: jnp.asarray(v) for k, v in host.items()}
        else:
            yield {
                k: jax.device_put(v, shardings[k]) for k, v in host.items()
            }
        step += 1
