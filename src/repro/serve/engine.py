"""Continuous-batching serving engine (prefill + decode over slot caches).

A fixed pool of B slots shares one batched decode cache.  New requests
prefill individually (at their own length bucket) and are inserted into a
free slot; every engine tick runs one batched decode step for all active
slots.  This is the standard production decode loop (vLLM-style at the
granularity JAX expresses naturally), with Opera's traffic classes at the
collective layer: decode MoE dispatch rides the rotor-direct schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.kvcache import init_cache
from repro.models.model import forward_decode, forward_prefill
from repro.models.parallel import ParallelContext


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (L,) int32
    max_new_tokens: int = 16
    eos_id: int = -1               # -1: never stop early
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        pctx: ParallelContext,
        slots: int = 4,
        max_seq: int = 128,
        greedy: bool = True,
    ):
        self.cfg = cfg
        self.params = params
        self.pctx = pctx
        self.slots = slots
        self.max_seq = max_seq
        self.greedy = greedy
        self.cache = init_cache(cfg, slots, max_seq)
        self.pos = np.zeros(slots, np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._decode = jax.jit(
            lambda p, t, q, c: forward_decode(p, t, q, c, cfg, pctx)
        )
        self._prefill = jax.jit(
            lambda p, b: forward_prefill(p, b, cfg, pctx)
        )

    # ---------------- request plumbing -------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _insert(self, slot: int, req: Request):
        L = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
        if self.cfg.family == "encdec":
            batch["encoder_embeds"] = jnp.zeros(
                (1, L, self.cfg.d_model), jnp.dtype(self.cfg.compute_dtype)
            )
        if self.cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (1, self.cfg.num_image_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype),
            )
        logits, pc = self._prefill(self.params, batch)
        # write the single-request cache into the batched slot.  prefill
        # caches have seq length L; pad into the slot's max_seq buffers.
        base_rank = {"k": 4, "v": 4, "ck": 4, "cv": 4,
                     "conv": 3, "ssm": 3, "lru": 2}

        def put(path, slot_leaf, pre_leaf):
            name = str(getattr(path[-1], "key", getattr(path[-1], "name", "")))
            bdim = slot_leaf.ndim - base_rank.get(name, slot_leaf.ndim)
            pads = [
                (0, slot_leaf.shape[ax] - pre_leaf.shape[ax])
                if ax != bdim else (0, 0)
                for ax in range(pre_leaf.ndim)
            ]
            pre = jnp.pad(pre_leaf, pads)
            row = jnp.take(pre, 0, axis=bdim)
            return jax.lax.dynamic_update_index_in_dim(
                slot_leaf, row.astype(slot_leaf.dtype), slot, axis=bdim
            )

        self.cache = jax.tree_util.tree_map_with_path(put, self.cache, pc)
        tok = int(jnp.argmax(logits[0])) if self.greedy else int(
            jax.random.categorical(jax.random.key(req.rid), logits[0])
        )
        req.out_tokens.append(tok)
        self.active[slot] = req
        self.pos[slot] = L

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    # ---------------- engine tick -------------------------------------------
    def step(self) -> int:
        """Admit queued requests, run one batched decode step.  Returns the
        number of active requests after the tick."""
        for slot in self._free_slots():
            if not self.queue:
                break
            self._insert(slot, self.queue.pop(0))

        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        toks = np.zeros((self.slots, 1), np.int32)
        for i in live:
            toks[i, 0] = self.active[i].out_tokens[-1]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), jnp.asarray(self.pos), self.cache
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in live:
            r = self.active[i]
            self.pos[i] += 1
            tok = int(nxt[i])
            r.out_tokens.append(tok)
            if (
                tok == r.eos_id
                or len(r.out_tokens) >= r.max_new_tokens
                or self.pos[i] >= self.max_seq - 1
            ):
                r.done = True
                self.finished.append(r)
                self.active[i] = None
        return sum(r is not None for r in self.active)

    def run_to_completion(self, max_ticks: int = 1000) -> List[Request]:
        for _ in range(max_ticks):
            self.step()
            if not self.queue and all(r is None for r in self.active):
                break
        return self.finished
