"""deepseek-moe-16b — [arXiv:2401.06066; hf]

28L d_model=2048 16H (MHA kv=16) moe_d_ff=1408 vocab=102400,
2 shared + 64 routed experts top-6, fine-grained. First layer is a dense
FFN with hidden 10944 (per the published config). Full attention ->
long_500k skipped.
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("deepseek-moe-16b")
def deepseek_moe_16b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,  # routed-expert hidden size
        vocab_size=102_400,
        act="silu",
        norm="rmsnorm",
        rope_theta=10_000.0,
        moe=MoEConfig(
            num_experts=64,
            top_k=6,
            d_ff_expert=1408,
            num_shared_experts=2,
            d_ff_shared=2 * 1408,
            first_dense_layers=1,
            d_ff_dense=10_944,
        ),
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skipped_shapes={
            "long_500k": "pure full-attention arch — long_500k requires "
            "sub-quadratic attention"
        },
        notes="fine-grained MoE with shared experts; skewed small-payload "
        "all-to-all exercises the RotorLB/VLB mode.",
    )
