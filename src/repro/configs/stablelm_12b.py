"""stablelm-12b — [hf:stabilityai/stablelm-2-1_6b; hf]

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352. LayerNorm family.
Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig, register


@register("stablelm-12b")
def stablelm_12b() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=160,
        d_ff=13_824,
        vocab_size=100_352,
        act="silu",
        norm="layernorm",
        rope_theta=10_000.0,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skipped_shapes={
            "long_500k": "pure full-attention arch — long_500k requires "
            "sub-quadratic attention"
        },
    )
