"""smollm-360m — [hf:HuggingFaceTB/SmolLM-135M; hf]

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152. llama-arch small.
Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig, register


@register("smollm-360m")
def smollm_360m() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        family="dense",
        num_layers=32,
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=49_152,
        act="silu",
        norm="rmsnorm",
        tie_embeddings=True,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skipped_shapes={
            "long_500k": "pure full-attention arch — long_500k requires "
            "sub-quadratic attention"
        },
        notes="smallest arch; DP/collective-bound cell (grad sync dominates).",
    )
