"""Architecture registry. Importing this package registers all configs."""
from repro.configs import (  # noqa: F401
    deepseek_moe_16b,
    falcon_mamba_7b,
    llama32_vision_90b,
    opera_paper,
    qwen15_110b,
    qwen3_moe_30b_a3b,
    recurrentgemma_2b,
    seamless_m4t_large_v2,
    smollm_360m,
    stablelm_12b,
    yi_9b,
)
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    MoEConfig,
    ShapeSpec,
    all_cells,
    get_config,
    input_specs,
    list_archs,
    runnable_shapes,
)

ALL_ARCHS = (
    "qwen3-moe-30b-a3b",
    "deepseek-moe-16b",
    "falcon-mamba-7b",
    "seamless-m4t-large-v2",
    "recurrentgemma-2b",
    "llama-3.2-vision-90b",
    "smollm-360m",
    "yi-9b",
    "qwen1.5-110b",
    "stablelm-12b",
)
