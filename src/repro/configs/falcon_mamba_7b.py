"""falcon-mamba-7b — [arXiv:2410.05355; unverified]

64L d_model=4096 attention-free Mamba-1, ssm_state=16, vocab=65024.
d_inner = 2*d_model = 8192, conv kernel 4, dt_rank = ceil(4096/16) = 256.
Recurrent (O(1)/token) -> runs long_500k.
"""
from repro.configs.base import ModelConfig, SSMConfig, register


@register("falcon-mamba-7b")
def falcon_mamba_7b() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        num_layers=64,
        d_model=4096,
        num_heads=1,       # unused (attention-free)
        num_kv_heads=1,
        head_dim=1,
        d_ff=0,            # no FFN: mamba block is the whole mixer
        vocab_size=65_024,
        act="silu",
        norm="rmsnorm",
        ssm=SSMConfig(state_dim=16, conv_kernel=4, expand=2),
        shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
        notes="mamba1 arch; decode state is O(d_inner*(state+conv)) per "
        "layer regardless of context length.",
    )
