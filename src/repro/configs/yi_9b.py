"""yi-9b — [arXiv:2403.04652; hf]

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000. llama-arch GQA.
Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig, register


@register("yi-9b")
def yi_9b() -> ModelConfig:
    return ModelConfig(
        name="yi-9b",
        family="dense",
        num_layers=48,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=11_008,
        vocab_size=64_000,
        act="silu",
        norm="rmsnorm",
        rope_theta=10_000.0,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skipped_shapes={
            "long_500k": "pure full-attention arch — long_500k requires "
            "sub-quadratic attention"
        },
    )
