"""seamless-m4t-large-v2 — [arXiv:2308.11596; hf]

Encoder-decoder transformer BACKBONE only (24 enc + 24 dec layers,
d_model=1024, 16H MHA, d_ff=8192, vocab=256206). The audio/modality
frontend is a STUB: input_specs() provides precomputed frame embeddings
(B, S, d_model). Full attention -> long_500k skipped. Has a decoder ->
decode shapes run (self-KV + cross-KV over encoder states).
"""
from repro.configs.base import ModelConfig, register


@register("seamless-m4t-large-v2")
def seamless_m4t_large_v2() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="encdec",
        num_layers=24,        # decoder layers
        encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab_size=256_206,
        act="relu",
        norm="layernorm",
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skipped_shapes={
            "long_500k": "pure full-attention enc-dec — long_500k requires "
            "sub-quadratic attention"
        },
        notes="multimodal enc-dec; frontend stubbed as precomputed frame "
        "embeddings per the assignment.",
    )
