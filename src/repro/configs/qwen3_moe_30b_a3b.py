"""qwen3-moe-30b-a3b — [hf:Qwen/Qwen3-30B-A3B; hf]

48L d_model=2048 32H (GQA kv=4) d_ff=768(expert) vocab=151936,
MoE 128 experts top-8, no shared experts, every layer sparse
(decoder_sparse_step=1, mlp_only_layers=[]). head_dim=128 and per-head
QK-norm per the published HF config. Full (global) attention -> long_500k
is skipped per the assignment's sub-quadratic rule.
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("qwen3-moe-30b-a3b")
def qwen3_moe_30b_a3b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,  # == expert hidden size; all FFNs are MoE
        vocab_size=151_936,
        qk_norm=True,
        act="silu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        moe=MoEConfig(
            num_experts=128,
            top_k=8,
            d_ff_expert=768,
        ),
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skipped_shapes={
            "long_500k": "pure full-attention arch (global softmax attention "
            "every layer) — long_500k requires sub-quadratic attention"
        },
        notes="128-expert top-8 MoE; the paper-technique showcase arch "
        "(rotor all-to-all expert dispatch == Opera bulk shuffle).",
    )
