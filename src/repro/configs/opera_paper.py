"""The paper's own network design points, used by netsim/ and benchmarks/.

All constants are taken from the text (§4, §5, Appendices A-B).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class OperaNetConfig:
    name: str
    k: int                    # ToR radix
    num_racks: int
    hosts_per_rack: int
    num_circuit_switches: int  # u = k/2 uplinks, one per switch
    link_rate_gbps: float = 10.0
    prop_delay_us: float = 0.5     # 100 m fiber between ToRs
    reconfig_delay_us: float = 10.0  # r, state-of-the-art optical switch
    epsilon_us: float = 90.0       # worst-case end-to-end delay (§4.1)
    queue_bytes: int = 24 * 1024   # shallow ToR queue (§4.1)
    mtu: int = 1500
    bulk_cutoff_bytes: int = 15 * 2**20  # flows >= 15 MB default to direct
    groups: int = 1                # switches reconfiguring simultaneously (App. B)

    @property
    def u(self) -> int:
        return self.num_circuit_switches

    @property
    def d(self) -> int:
        return self.hosts_per_rack

    @property
    def num_hosts(self) -> int:
        return self.num_racks * self.hosts_per_rack


# The concrete 648-host design point used throughout §4-§5:
# k = 12, d = u = 6, 108 racks, 6 rotor switches, 108 disjoint matchings
# (N/u = 18 per switch).
OPERA_648 = OperaNetConfig(
    name="opera-648",
    k=12,
    num_racks=108,
    hosts_per_rack=6,
    num_circuit_switches=6,
)

# The 5184-host scale point (§5.6): k = 24, d = u = 12.
OPERA_5184 = OperaNetConfig(
    name="opera-5184",
    k=24,
    num_racks=432,
    hosts_per_rack=12,
    num_circuit_switches=12,
)

# Cost-equivalent comparison points (§5, Fig. 2/4/7):
#   u=7 static expander with 650 hosts (130 racks x 5 hosts, k=12)
#   3:1 folded Clos with 648 hosts
EXPANDER_650 = dict(name="expander-650", k=12, num_racks=130, hosts_per_rack=5, u=7)
CLOS_648 = dict(name="clos-648", k=12, num_hosts=648, oversubscription=3)

ALPHA_OPERA = 1.3  # Appendix A cost ratio of an Opera port vs a static port
