"""recurrentgemma-2b — [arXiv:2402.19427; hf]

26L d_model=2560 10H (MQA kv=1, head_dim=256) d_ff=7680 vocab=256000.
Griffin block pattern: (rglru, rglru, local_attn) repeating, window 2048,
lru_width=2560. Sub-quadratic (local attention + recurrent state) ->
runs long_500k.
"""
from repro.configs.base import HybridConfig, ModelConfig, register


@register("recurrentgemma-2b")
def recurrentgemma_2b() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256_000,
        act="gelu",
        norm="rmsnorm",
        tie_embeddings=True,
        hybrid=HybridConfig(
            pattern=("rglru", "rglru", "local_attn"),
            local_window=2048,
            lru_width=2560,
        ),
        shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
        notes="RG-LRU + local attention 1:2; decode state = LRU state + a "
        "fixed 2048-token local KV window regardless of context.",
    )
