"""llama-3.2-vision-90b — [hf:meta-llama/Llama-3.2-11B-Vision; unverified]

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. Every 5th layer
is a cross-attention layer over image tokens (20 cross + 80 self, matching
the 11B->90B scaling of the published cross_attention_layers pattern).
The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, num_image_tokens, d_model). Full attention -> long_500k
skipped.
"""
from repro.configs.base import ModelConfig, register


@register("llama-3.2-vision-90b")
def llama32_vision_90b() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28_672,
        vocab_size=128_256,
        act="silu",
        norm="rmsnorm",
        rope_theta=500_000.0,
        cross_attn_every=5,
        num_image_tokens=1600,  # 1601 in HF (tile 448/14 + cls); 1600 keeps
        # the token dim mesh-divisible, delta noted in DESIGN.md
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skipped_shapes={
            "long_500k": "pure full-attention arch — long_500k requires "
            "sub-quadratic attention"
        },
        notes="largest assigned arch (~88B); FSDP+TP stress cell.",
    )
