"""Model / run configuration system.

Every assigned architecture is a `ModelConfig` registered under its public
id (``--arch <id>``).  Each architecture carries its own input-shape set
(`SHAPES`), and `input_specs(cfg, shape, ...)` produces the
`jax.ShapeDtypeStruct` stand-ins used by the multi-pod dry-run (no device
allocation, weak-type correct, shardable).

Nothing in this module touches jax device state at import time.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Shape set shared by the LM-family architectures (per assignment).
# decode_* / long_* lower `serve_step` (one new token against a KV cache of
# seq_len), NOT `train_step`.  long_500k runs only for sub-quadratic archs.
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0           # routed experts
    top_k: int = 0
    d_ff_expert: int = 0           # per-expert FFN hidden size
    num_shared_experts: int = 0    # always-on shared experts (DeepSeekMoE)
    d_ff_shared: int = 0           # total hidden size of the shared branch
    first_dense_layers: int = 0    # leading layers that use a dense FFN
    d_ff_dense: int = 0            # hidden size for those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_kernel: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class HybridConfig:
    # RecurrentGemma-style block pattern, repeated (+ truncated) to num_layers.
    pattern: Tuple[str, ...] = ()  # entries: "rglru" | "local_attn"
    local_window: int = 2048
    lru_width: int = 0             # 0 -> d_model


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    act: str = "silu"              # silu | gelu | relu
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # enc-dec (family == "encdec"): num_layers counts DECODER layers.
    encoder_layers: int = 0
    # vlm: every `cross_attn_every`-th layer is a cross-attention layer;
    # cross-attn layers are *included* in num_layers (Llama-3.2-V style).
    cross_attn_every: int = 0
    num_image_tokens: int = 0
    # shape-set policy
    shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    skipped_shapes: Dict[str, str] = field(default_factory=dict)
    # numerics / distribution knobs (overridable per run)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"            # full | none
    norm_upcast: bool = True       # False: bf16 normalize (fp32 reductions)
    loss_chunk_vocab: int = 0      # >0: vocab-chunked CE (no full logits)
    grad_sync: str = "rotor"       # rotor | xla    (inter-pod gradient sync)
    moe_dispatch: str = "rotor"    # rotor | xla | rotor_vlb
    notes: str = ""

    # ---------------- derived -------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def dt_rank_(self) -> int:
        if self.ssm is None:
            return 0
        return self.ssm.dt_rank or -(-self.d_model // 16)

    @property
    def d_inner_(self) -> int:
        return 0 if self.ssm is None else self.ssm.expand * self.d_model

    @property
    def lru_width_(self) -> int:
        if self.hybrid is None:
            return 0
        return self.hybrid.lru_width or self.d_model

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind for heterogeneous stacks."""
        if self.family == "hybrid":
            p = self.hybrid.pattern
            return tuple(p[i % len(p)] for i in range(self.num_layers))
        if self.family == "vlm" and self.cross_attn_every:
            return tuple(
                "cross_attn" if (i + 1) % self.cross_attn_every == 0 else "self_attn"
                for i in range(self.num_layers)
            )
        if self.family == "moe":
            m = self.moe
            return tuple(
                "dense" if i < m.first_dense_layers else "moe"
                for i in range(self.num_layers)
            )
        if self.family == "ssm":
            return ("ssm",) * self.num_layers
        return ("self_attn",) * self.num_layers

    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementation)."""
        from repro.models.model import count_params  # local import, no cycle

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params

        return count_params(self, active_only=True)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import side-effect registration of all arch modules
        from repro.configs import ALL_ARCHS  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> Tuple[str, ...]:
    from repro.configs import ALL_ARCHS  # noqa: F401

    return tuple(sorted(_REGISTRY))


# --------------------------------------------------------------------------
# Dry-run input specs: ShapeDtypeStruct stand-ins for every model input.
# --------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the batch of a given (arch, shape) cell.

    train:   token/target ids (+ modality-frontend stubs).
    prefill: token ids only (logits + fresh cache out).
    decode:  one new token per sequence + the standing cache/state.
    """
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    specs: Dict[str, Any] = {}

    if kind == "train":
        if cfg.family == "encdec":
            # audio frontend stub: precomputed frame embeddings
            specs["encoder_embeds"] = _sds((B, S, cfg.d_model), cfg.compute_dtype)
            specs["tokens"] = _sds((B, S), "int32")
            specs["targets"] = _sds((B, S), "int32")
        else:
            specs["tokens"] = _sds((B, S), "int32")
            specs["targets"] = _sds((B, S), "int32")
        if cfg.family == "vlm":
            specs["image_embeds"] = _sds(
                (B, cfg.num_image_tokens, cfg.d_model), cfg.compute_dtype
            )
    elif kind == "prefill":
        if cfg.family == "encdec":
            specs["encoder_embeds"] = _sds((B, S, cfg.d_model), cfg.compute_dtype)
        specs["tokens"] = _sds((B, S), "int32")
        if cfg.family == "vlm":
            specs["image_embeds"] = _sds(
                (B, cfg.num_image_tokens, cfg.d_model), cfg.compute_dtype
            )
    elif kind == "decode":
        specs["tokens"] = _sds((B, 1), "int32")
        specs["positions"] = _sds((B,), "int32")
        # the standing cache/state is built by models.kvcache.cache_specs()
    else:
        raise ValueError(kind)
    return specs


def runnable_shapes(cfg: ModelConfig) -> Tuple[ShapeSpec, ...]:
    return tuple(SHAPES[s] for s in cfg.shapes)


def all_cells():
    """Every (arch × shape) cell, runnable and skipped alike."""
    out = []
    for arch in list_archs():
        cfg = get_config(arch)
        for sname, spec in SHAPES.items():
            if sname in cfg.shapes:
                out.append((arch, sname, "run"))
            else:
                out.append((arch, sname, cfg.skipped_shapes.get(sname, "skip")))
    return out


# --------------------------------------------------------------------------
# Reduced configs for CPU smoke tests: same family/structure, tiny dims.
# --------------------------------------------------------------------------


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    kw: Dict[str, Any] = dict(
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
    if cfg.family == "moe":
        kw["num_layers"] = 3
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=8,
            top_k=2,
            d_ff_expert=32,
            d_ff_shared=64 if cfg.moe.num_shared_experts else 0,
            d_ff_dense=128 if cfg.moe.first_dense_layers else 0,
        )
    elif cfg.family == "ssm":
        kw["num_layers"] = 2
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=4)
        kw["num_heads"] = 1
        kw["num_kv_heads"] = 1
        kw["head_dim"] = 1
        kw["d_ff"] = 0
    elif cfg.family == "hybrid":
        kw["num_layers"] = 5  # pattern(3) x 1 + tail 2 — exercises the plan
        kw["hybrid"] = dataclasses.replace(
            cfg.hybrid, local_window=8, lru_width=64
        )
    elif cfg.family == "encdec":
        kw["num_layers"] = 2
        kw["encoder_layers"] = 2
    elif cfg.family == "vlm":
        kw["num_layers"] = 4
        kw["cross_attn_every"] = 2
        kw["num_image_tokens"] = 8
    else:
        kw["num_layers"] = 2
    return cfg.replace(**kw)
