"""qwen1.5-110b — [hf:Qwen/Qwen1.5-0.5B; hf]

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064, QKV bias.
Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig, register


@register("qwen1.5-110b")
def qwen15_110b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=49_152,
        vocab_size=152_064,
        qkv_bias=True,
        act="silu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skipped_shapes={
            "long_500k": "pure full-attention arch — long_500k requires "
            "sub-quadratic attention"
        },
        notes="largest dense arch (~111B); memory-roofline stress cell.",
    )
