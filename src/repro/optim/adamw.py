"""AdamW with cosine schedule, global-norm clipping, decoupled decay.

Built from scratch (no optax in this environment).  Optimizer state is a
pytree shaped like the params (same shardings), so FSDP sharding of the
moments comes for free from the param sharding rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(c: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = c.lr * jnp.minimum(1.0, (step + 1) / max(c.warmup_steps, 1))
    t = jnp.clip(
        (step - c.warmup_steps) / max(c.total_steps - c.warmup_steps, 1), 0, 1
    )
    cos = c.min_lr_frac + (1 - c.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < c.warmup_steps, warm, c.lr * cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(  # noqa: E731
        lambda x: jnp.zeros(x.shape, jnp.float32), p
    )
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def _decay_mask(path) -> bool:
    """No decay for norms / biases / 1-d params."""
    name = getattr(path[-1], "key", getattr(path[-1], "name", ""))
    return name not in ("scale", "bias", "b_in", "b_out", "bq", "bk", "bv",
                        "dt_bias", "lambda", "D")


def adamw_update(
    c: AdamWConfig, params, grads, opt_state
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(c, step)
    b1, b2 = c.beta1, c.beta2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - b1**t
    bc2 = 1 - b2**t

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + c.eps)
        if _decay_mask(path):
            delta = delta + c.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, opt_state["m"], opt_state["v"],
    )
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
