"""Attention: GQA with RoPE, chunked (flash-style) softmax, sliding-window
block-local attention, decode against a KV cache, and cross-attention.

The chunked jnp implementation is the oracle for the Pallas flash kernel
(kernels/flash_attention) AND the default XLA path for the dry-run: it
never materializes the full (S x S) score matrix, so the memory-roofline
term reflects a production attention, not a naive one.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_head_norm

NEG_INF = -1e30


# ---------------- params ---------------------------------------------------


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    hd = cfg.head_dim_
    dq = cfg.num_heads * hd
    dkv = cfg.num_kv_heads * hd
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, dq, dt),
        "wk": dense_init(ks[1], cfg.d_model, dkv, dt),
        "wv": dense_init(ks[2], cfg.d_model, dkv, dt),
        "wo": dense_init(ks[3], dq, cfg.d_model, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((dq,), dt)
        p["bk"] = jnp.zeros((dkv,), dt)
        p["bv"] = jnp.zeros((dkv,), dt)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _project_q(p, x, cfg: ModelConfig):
    B, S, _ = x.shape
    hd = cfg.head_dim_
    q = x @ p["wq"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, S, cfg.num_heads, hd).transpose(0, 2, 1, 3)
    if "q_norm" in p:
        q = rms_head_norm(p["q_norm"], q)
    return q  # (B, Hq, S, hd)


def _project_kv(p, x, cfg: ModelConfig):
    B, S, _ = x.shape
    hd = cfg.head_dim_
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bk" in p:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    k = k.reshape(B, S, cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
    if "k_norm" in p:
        k = rms_head_norm(p["k_norm"], k)
    return k, v  # (B, Hkv, S, hd)


# ---------------- chunked flash-style softmax ------------------------------


def _pick_chunk(S: int, target: int) -> int:
    c = min(target, S)
    while S % c:
        c -= 1
    return max(c, 1)


def chunked_attention(
    q: jnp.ndarray,          # (B, Hq, Sq, hd)
    k: jnp.ndarray,          # (B, Hkv, Sk, hd)
    v: jnp.ndarray,
    q_pos: jnp.ndarray,      # (Sq,) int32
    kv_pos: jnp.ndarray,     # (Sk,) int32
    causal: bool = True,
    window: int = 0,
    chunk_q: int = 512,
    chunk_k: int = 1024,
) -> jnp.ndarray:
    """Online-softmax attention, never materializing (Sq x Sk)."""
    B, Hq, Sq, hd = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = hd**-0.5
    cq = _pick_chunk(Sq, chunk_q)
    ck = _pick_chunk(k.shape[2], chunk_k)
    nq, nk = Sq // cq, k.shape[2] // ck

    qg = q.reshape(B, Hkv, G, nq, cq, hd).transpose(3, 0, 1, 2, 4, 5)
    qp = q_pos.reshape(nq, cq)
    kc = k.reshape(B, Hkv, nk, ck, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, nk, ck, hd).transpose(2, 0, 1, 3, 4)
    kp = kv_pos.reshape(nk, ck)

    def per_q_chunk(_, qx):
        qc, qpc = qx  # (B,Hkv,G,cq,hd), (cq,)

        def per_k_chunk(carry, kx):
            m, l, acc = carry
            kcc, vcc, kpc = kx
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qc.astype(jnp.float32),
                kcc.astype(jnp.float32),
            ) * scale
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= kpc[None, :] <= qpc[:, None]
            if window > 0:
                mask &= (qpc[:, None] - kpc[None, :]) < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vcc.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, cq, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(per_k_chunk, (m0, l0, a0), (kc, vc, kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, outs = lax.scan(per_q_chunk, None, (qg, qp))
    # outs: (nq, B, Hkv, G, cq, hd) -> (B, Hq, Sq, hd)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv * G, Sq, hd)
    return out.astype(q.dtype)


def block_local_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    q_pos: jnp.ndarray, window: int,
) -> jnp.ndarray:
    """Sliding-window attention in O(S * 2W): each query block of size W
    attends to its own and the previous key block (covers any window <= W).
    """
    B, Hq, S, hd = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    W = min(window, S)
    S_in = S
    if S % W:  # pad to a block multiple; padded keys are causally masked
        pad = W - S % W
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        S = S + pad
    nb = S // W
    scale = hd**-0.5

    qb = q.reshape(B, Hkv, G, nb, W, hd)
    kb = k.reshape(B, Hkv, nb, W, hd)
    vb = v.reshape(B, Hkv, nb, W, hd)
    # previous block (zeros before block 0)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :, :1]), kb[:, :, :-1]], axis=2)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :, :1]), vb[:, :, :-1]], axis=2)
    k2 = jnp.concatenate([kprev, kb], axis=3)  # (B,Hkv,nb,2W,hd)
    v2 = jnp.concatenate([vprev, vb], axis=3)

    s = jnp.einsum(
        "bhgnqd,bhnkd->bhgnqk", qb.astype(jnp.float32), k2.astype(jnp.float32)
    ) * scale
    qi = jnp.arange(W)
    ki = jnp.arange(2 * W) - W  # relative to block start
    rel = qi[:, None] - ki[None, :]  # distance q - k
    mask = (rel >= 0) & (rel < W if window >= S else rel < window)
    # block 0 has no previous block
    blk0 = jnp.arange(nb) == 0
    mask_full = mask[None, :, :] & ~(blk0[:, None, None] & (ki < 0)[None, None, :])
    s = jnp.where(mask_full[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgnqk,bhnkd->bhgnqd", p, v2.astype(jnp.float32))
    return out.reshape(B, Hq, S, hd)[:, :, :S_in].astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,          # (B, Hq, 1, hd)
    k_cache: jnp.ndarray,    # (B, Hkv, S, hd)
    v_cache: jnp.ndarray,
    kv_len: jnp.ndarray,     # (B,) or scalar: #valid cache entries
    window: int = 0,
) -> jnp.ndarray:
    B, Hq, _, hd = q.shape
    Hkv = k_cache.shape[1]
    G = Hq // Hkv
    S = k_cache.shape[2]
    scale = hd**-0.5
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum(
        "bhgd,bhkd->bhgk", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    idx = jnp.arange(S)
    valid = idx[None, :] < jnp.reshape(kv_len, (-1, 1))
    if window > 0:
        valid &= idx[None, :] >= (jnp.reshape(kv_len, (-1, 1)) - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, 1, hd).astype(q.dtype)


# ---------------- module-level apply ---------------------------------------


def attention_block(
    p: Dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,          # (S,)
    window: int = 0,
    use_rope: bool = True,
    causal: bool = True,
    return_kv: bool = False,
):
    """Self-attention over a full sequence (train / prefill).

    With return_kv=True also returns the (roped) K/V actually used — the
    exact tensors a decode cache must contain (trailing `window` slice for
    local attention).
    """
    q = _project_q(p, x, cfg)
    k, v = _project_kv(p, x, cfg)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if causal and window > 0 and x.shape[1] > window:
        o = block_local_attention(q, k, v, positions, window)
    else:
        o = chunked_attention(q, k, v, positions, positions, causal=causal,
                              window=window if window > 0 else 0)
    B, S, _ = x.shape
    o = o.transpose(0, 2, 1, 3).reshape(B, S, -1)
    y = o @ p["wo"].astype(x.dtype)
    if return_kv:
        if window > 0 and S >= window:
            # trailing window, rolled so slot(t) == t % window matches the
            # ring-buffer writes of attention_block_decode
            k, v = k[:, :, -window:], v[:, :, -window:]
            k = jnp.roll(k, S % window, axis=2)
            v = jnp.roll(v, S % window, axis=2)
        return y, k, v
    return y


def attention_block_decode(
    p: Dict,
    x: jnp.ndarray,                   # (B, 1, D)
    cfg: ModelConfig,
    pos: jnp.ndarray,                 # (B,) current position
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    window: int = 0,
    use_rope: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step: write new KV at `pos`, attend over the cache."""
    q = _project_q(p, x, cfg)
    k, v = _project_kv(p, x, cfg)
    if use_rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    S = k_cache.shape[2]
    if window > 0 and S == window:
        # rolling window cache: write at pos % window
        slot = pos % window
    else:
        slot = jnp.minimum(pos, S - 1)
    bidx = jnp.arange(x.shape[0])
    k_cache = k_cache.at[bidx, :, slot].set(k[:, :, 0])
    v_cache = v_cache.at[bidx, :, slot].set(v[:, :, 0])
    kv_len = jnp.minimum(pos + 1, S)
    o = decode_attention(q, k_cache, v_cache, kv_len,
                         window=0 if (window > 0 and S == window) else window)
    o = o.reshape(x.shape[0], 1, -1)
    return o @ p["wo"].astype(x.dtype), k_cache, v_cache


def cross_attention_block(
    p: Dict,
    x: jnp.ndarray,                   # (B, S, D)
    cfg: ModelConfig,
    cross_k: jnp.ndarray,             # (B, Hkv, Sx, hd) precomputed
    cross_v: jnp.ndarray,
) -> jnp.ndarray:
    q = _project_q(p, x, cfg)
    B, S, _ = x.shape
    Sx = cross_k.shape[2]
    qpos = jnp.arange(S, dtype=jnp.int32)
    kpos = jnp.arange(Sx, dtype=jnp.int32)
    o = chunked_attention(q, cross_k, cross_v, qpos, kpos, causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, -1)
    return o @ p["wo"].astype(x.dtype)


def project_cross_kv(p: Dict, src: jnp.ndarray, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder states / image embeds."""
    return _project_kv(p, src, cfg)
