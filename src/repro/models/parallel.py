"""Parallel context threaded through model builders.

Carries the mesh + axis names and the Opera scheduling choices
(bulk-class dispatch for MoE all-to-all, gradient sync flavor).  When
`mesh` is None the models run as plain single-device jnp (smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Optional[jax.sharding.Mesh] = None
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    pod_axis: Optional[str] = None          # set on multi-pod meshes
    moe_dispatch: str = "rotor"             # rotor | rotor_vlb | xla | local
    grad_sync: str = "rotor"                # rotor | xla
    use_pallas: bool = False                # TPU hot-path kernels
    act_sharding: str = "dp"                # dp | sp (seq over model axis)
    # layout levers (perf hillclimb, EXPERIMENTS.md §Perf):
    #   fsdp_tp (default) — params sharded over data (ZeRO) x model (TP)
    #   dp_only           — model axis repurposed as extra data parallelism
    #                       (archs whose head counts don't divide tp)
    #   tp_only           — params resident TP-sharded only (no FSDP
    #                       gathering; the decode/serving layout)
    layout: str = "fsdp_tp"

    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.layout == "dp_only":
            return 1
        return int(self.mesh.shape[self.tp_axis])

    @property
    def fsdp_params(self) -> bool:
        return self.layout != "tp_only"

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(self.dp_axes) + (self.tp_axis,)


def single_device_ctx(**kw) -> ParallelContext:
    return ParallelContext(mesh=None, **kw)
