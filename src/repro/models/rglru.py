"""Griffin/RecurrentGemma recurrent block: causal conv + RG-LRU, gated.

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(W_a x_t)                       (recurrence gate)
    i_t = sigmoid(W_x x_t)                       (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)       (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The diagonal recurrence is solved with an associative scan (train /
prefill) or a single step (decode).  The full recurrent block is:
    y = W_out( gelu(W_y x) * RG-LRU(conv1d(W_x' x)) )
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import apply_causal_conv, dense_init, init_causal_conv

_C = 8.0


def init_rglru_block(key, cfg: ModelConfig) -> Dict:
    D = cfg.d_model
    Dl = cfg.lru_width_
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    # Lambda init so that a^c in [0.9, 0.999] (paper appendix)
    u = jax.random.uniform(ks[5], (Dl,), minval=0.9**2, maxval=0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * _C)))  # softplus^-1
    return {
        "w_y": dense_init(ks[0], D, Dl, dt),
        "w_x": dense_init(ks[1], D, Dl, dt),
        "conv": init_causal_conv(ks[2], Dl, 4, dt),
        "w_a": dense_init(ks[3], Dl, Dl, dt),
        "w_i": dense_init(ks[4], Dl, Dl, dt),
        "lambda": lam.astype(jnp.float32),
        "w_out": dense_init(ks[0], Dl, D, dt),
    }


def _gates(p, x):
    r = jax.nn.sigmoid((x @ p["w_a"].astype(x.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["w_i"].astype(x.dtype)).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * x.astype(jnp.float32))


def rglru_scan(p: Dict, x: jnp.ndarray, h0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, Dl); h0: (B, Dl).  Returns (h_seq, h_last)."""
    a, bx = _gates(p, x)
    bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, hs = lax.associative_scan(combine, (a, bx), axis=1)
    return hs, hs[:, -1]


def rglru_block_mix(
    p: Dict, u: jnp.ndarray, cfg: ModelConfig, return_state: bool = False
):
    """Full-sequence recurrent block (train / prefill)."""
    B, S, D = u.shape
    Dl = cfg.lru_width_
    y_branch = jax.nn.gelu(u @ p["w_y"].astype(u.dtype))
    x_pre = u @ p["w_x"].astype(u.dtype)
    x, _ = apply_causal_conv(p["conv"], x_pre)
    h0 = jnp.zeros((B, Dl), jnp.float32)
    hs, h_last = rglru_scan(p, x, h0)
    out = hs.astype(u.dtype) * y_branch
    out = out @ p["w_out"].astype(u.dtype)
    if return_state:
        return out, x_pre[:, -3:, :], h_last
    return out


def rglru_block_decode(
    p: Dict,
    u: jnp.ndarray,            # (B, 1, D)
    cfg: ModelConfig,
    conv_state: jnp.ndarray,   # (B, K-1, Dl)
    lru_state: jnp.ndarray,    # (B, Dl)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    y_branch = jax.nn.gelu(u @ p["w_y"].astype(u.dtype))
    x = u @ p["w_x"].astype(u.dtype)
    x, conv_state = apply_causal_conv(p["conv"], x, conv_state)
    a, bx = _gates(p, x)
    h = a[:, 0] * lru_state + bx[:, 0]
    out = h[:, None].astype(u.dtype) * y_branch
    return out @ p["w_out"].astype(u.dtype), conv_state, h
