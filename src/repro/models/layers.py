"""Primitive layers: inits, norms, rotary embeddings, activations.

Parameters are plain nested dicts of jnp arrays (no flax/optax in this
environment — the substrate is built from scratch).  Params are stored in
`cfg.param_dtype` (fp32 master) and cast to `cfg.compute_dtype` at use.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def normal_init(key, shape, scale: float, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    """Fan-in scaled normal (std = 1/sqrt(d_in))."""
    return normal_init(key, (d_in, d_out), d_in**-0.5, dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    # d^-0.5 keeps tied-head logits O(1) at init
    return normal_init(key, (vocab, d), d**-0.5, dtype)


def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
    }[name]


# ---------------- norms ----------------------------------------------------


def init_norm(kind: str, d: int, dtype) -> Dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(kind: str, p: Dict, x: jnp.ndarray, eps: float = 1e-6,
               upcast: bool = True):
    """upcast=True materializes the normalized stream in fp32 (safest);
    upcast=False keeps the reduction in fp32 but the normalize/scale in
    the compute dtype — halves residual-stream HBM traffic (§Perf C2)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps)
        if not upcast:
            return x * inv.astype(dt) * p["scale"].astype(dt)
        y = x32 * inv
    else:  # layernorm
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps)
        if not upcast:
            y = (x - mu.astype(dt)) * inv.astype(dt) * p["scale"].astype(dt)
            return y + p["bias"].astype(dt) if "bias" in p else y
        y = (x32 - mu) * inv
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(dt)


def rms_head_norm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6):
    """Per-head QK-norm (Qwen3): normalize over the head_dim axis."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# ---------------- rotary ----------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """x: (..., seq, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta), dtype=jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, hd/2)
    if positions.ndim == 2:  # (B, seq): align with (B, H, seq, hd/2)
        ang = ang[:, None, :, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------- causal depthwise conv (mamba / griffin) ------------------


def init_causal_conv(key, channels: int, kernel: int, dtype) -> Dict:
    k1, _ = jax.random.split(key)
    return {
        "w": normal_init(k1, (channels, kernel), kernel**-0.5, dtype),
        "b": jnp.zeros((channels,), dtype),
    }


def apply_causal_conv(
    p: Dict, x: jnp.ndarray, state: Optional[jnp.ndarray] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d.  x: (B, S, C).  state: (B, K-1, C) carries
    the last K-1 inputs for decode.  Returns (y, new_state)."""
    w = p["w"].astype(x.dtype)  # (C, K)
    b = p["b"].astype(x.dtype)
    K = w.shape[1]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+K-1, C)
    # gather K shifted views; cheap vs conv_general for depthwise-small-K
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[:, i] for i in range(K)
    )
    y = y + b
    new_state = xp[:, -(K - 1) :, :] if K > 1 else state
    return y, new_state
