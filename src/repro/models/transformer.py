"""Heterogeneous transformer stacks with scan-over-layers.

`stack_plan(cfg)` splits the layer-kind sequence into
    prefix (unrolled) + pattern x n_scan (lax.scan superblocks) + tail,
so every assigned architecture — uniform dense/MoE/SSM stacks, Griffin's
(rglru, rglru, local_attn) period-3 pattern, Llama-3.2-V's every-5th
cross-attention layer, and the Seamless enc-dec — compiles to a compact
HLO regardless of depth (critical for 80-100 layer dry-runs).

Three modes per layer: "train"/"prefill" (full sequence, prefill also
emits the decode state) and "decode" (one token against the state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import ffn as F
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.layers import apply_norm, init_norm
from repro.models.parallel import ParallelContext


# --------------------------------------------------------------------------
# stack plan
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StackPlan:
    prefix: Tuple[str, ...]
    pattern: Tuple[str, ...]
    n_scan: int
    tail: Tuple[str, ...]

    @property
    def kinds(self) -> Tuple[str, ...]:
        return self.prefix + self.pattern * self.n_scan + self.tail


def stack_plan(cfg: ModelConfig) -> StackPlan:
    kinds = cfg.layer_kinds()
    if cfg.family == "encdec":
        return StackPlan((), ("decoder",), cfg.num_layers, ())
    if cfg.family == "moe" and cfg.moe.first_dense_layers:
        r = cfg.moe.first_dense_layers
        return StackPlan(tuple(kinds[:r]), ("moe",), cfg.num_layers - r, ())
    if cfg.family == "hybrid":
        p = cfg.hybrid.pattern
        n = cfg.num_layers // len(p)
        rem = cfg.num_layers % len(p)
        return StackPlan((), tuple(p), n, tuple(kinds[len(p) * n :]))
    if cfg.family == "vlm" and cfg.cross_attn_every:
        pe = cfg.cross_attn_every
        assert cfg.num_layers % pe == 0
        pat = tuple(
            "cross_attn" if i == pe - 1 else "self_attn" for i in range(pe)
        )
        return StackPlan((), pat, cfg.num_layers // pe, ())
    # uniform
    return StackPlan((), (kinds[0],), cfg.num_layers, ())


def encoder_plan(cfg: ModelConfig) -> StackPlan:
    return StackPlan((), ("encoder",), cfg.encoder_layers, ())


# --------------------------------------------------------------------------
# per-layer init
# --------------------------------------------------------------------------


def init_layer(key, cfg: ModelConfig, kind: str) -> Dict:
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    n = lambda: init_norm(cfg.norm, cfg.d_model, dt)  # noqa: E731
    if kind == "ssm":
        return {"ln1": n(), "mixer": S.init_mamba(ks[0], cfg)}
    if kind == "rglru":
        return {
            "ln1": n(),
            "rec": R.init_rglru_block(ks[0], cfg),
            "ln2": n(),
            "ffn": F.init_ffn(ks[1], cfg),
        }
    if kind == "local_attn":
        return {
            "ln1": n(),
            "attn": A.init_attention(ks[0], cfg),
            "ln2": n(),
            "ffn": F.init_ffn(ks[1], cfg),
        }
    if kind == "cross_attn":
        return {
            "ln1": n(),
            "attn": A.init_attention(ks[0], cfg, cross=True),
            "ln2": n(),
            "ffn": F.init_ffn(ks[1], cfg),
        }
    if kind == "decoder":
        return {
            "ln1": n(),
            "attn": A.init_attention(ks[0], cfg),
            "ln_x": n(),
            "xattn": A.init_attention(ks[1], cfg, cross=True),
            "ln2": n(),
            "ffn": F.init_ffn(ks[2], cfg),
        }
    if kind == "encoder":
        return {
            "ln1": n(),
            "attn": A.init_attention(ks[0], cfg),
            "ln2": n(),
            "ffn": F.init_ffn(ks[1], cfg),
        }
    if kind == "moe":
        return {
            "ln1": n(),
            "attn": A.init_attention(ks[0], cfg),
            "ln2": n(),
            "moe": M.init_moe(ks[1], cfg),
        }
    if kind == "dense":
        d_ff = cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense) else cfg.d_ff
        return {
            "ln1": n(),
            "attn": A.init_attention(ks[0], cfg),
            "ln2": n(),
            "ffn": F.init_ffn(ks[1], cfg, d_ff=d_ff),
        }
    # self_attn
    return {
        "ln1": n(),
        "attn": A.init_attention(ks[0], cfg),
        "ln2": n(),
        "ffn": F.init_ffn(ks[1], cfg),
    }


# --------------------------------------------------------------------------
# per-layer apply
# --------------------------------------------------------------------------


@dataclasses.dataclass
class LayerCtx:
    positions: Optional[jnp.ndarray] = None   # (S,) train/prefill
    pos: Optional[jnp.ndarray] = None          # (B,) decode position
    cross_src: Optional[jnp.ndarray] = None    # (B, Sx, D) enc/image embeds
    mode: str = "train"                        # train | prefill | decode


def _constrain(x, cfg, pctx: ParallelContext):
    if pctx.mesh is None:
        return x
    if pctx.act_sharding == "sp" and x.ndim == 3 and x.shape[1] % pctx.tp_size == 0:
        spec = P(tuple(pctx.dp_axes), pctx.tp_axis, None)
    else:
        spec = P(tuple(pctx.dp_axes), *([None] * (x.ndim - 1)))
    return lax.with_sharding_constraint(x, jax.NamedSharding(pctx.mesh, spec))


def apply_layer(
    kind: str,
    p: Dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    pctx: ParallelContext,
    ctx: LayerCtx,
    cache: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict]]:
    """Returns (x, aux_loss, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    mode = ctx.mode
    window = 0
    if kind == "local_attn":
        window = cfg.hybrid.local_window

    def norm(name, h):
        return apply_norm(cfg.norm, p[name], h, upcast=cfg.norm_upcast)

    new_cache: Optional[Dict] = None

    # ---- mixer sublayer --------------------------------------------------
    h = norm("ln1", x)
    if kind == "ssm":
        if mode == "decode":
            y, cs, ss = S.mamba_decode(p["mixer"], h, cfg, cache["conv"], cache["ssm"])
            new_cache = {"conv": cs, "ssm": ss}
        elif mode == "prefill":
            y, cs, ss = S.mamba_mix(p["mixer"], h, cfg, return_state=True)
            new_cache = {"conv": cs, "ssm": ss}
        else:
            y = S.mamba_mix(p["mixer"], h, cfg)
        return _constrain(x + y, cfg, pctx), aux, new_cache

    if kind == "rglru":
        if mode == "decode":
            y, cs, hs = R.rglru_block_decode(p["rec"], h, cfg, cache["conv"], cache["lru"])
            new_cache = {"conv": cs, "lru": hs}
        elif mode == "prefill":
            y, cs, hs = R.rglru_block_mix(p["rec"], h, cfg, return_state=True)
            new_cache = {"conv": cs, "lru": hs}
        else:
            y = R.rglru_block_mix(p["rec"], h, cfg)
        x = x + y
    elif kind == "cross_attn":
        if mode == "decode":
            ck, cv = cache["ck"], cache["cv"]
            new_cache = {"ck": ck, "cv": cv}
        else:
            ck, cv = A.project_cross_kv(p["attn"], ctx.cross_src, cfg)
            if mode == "prefill":
                new_cache = {"ck": ck, "cv": cv}
        y = A.cross_attention_block(p["attn"], h, cfg, ck, cv)
        x = x + y
    elif kind == "decoder":
        if mode == "decode":
            y, nk, nv = A.attention_block_decode(
                p["attn"], h, cfg, ctx.pos, cache["k"], cache["v"]
            )
            new_cache = {"k": nk, "v": nv, "ck": cache["ck"], "cv": cache["cv"]}
        elif mode == "prefill":
            y, kc, vc = A.attention_block(
                p["attn"], h, cfg, ctx.positions, return_kv=True
            )
            new_cache = {"k": kc, "v": vc}
        else:
            y = A.attention_block(p["attn"], h, cfg, ctx.positions)
        x = x + y
        h = norm("ln_x", x)
        if mode == "decode":
            ck, cv = cache["ck"], cache["cv"]
        else:
            ck, cv = A.project_cross_kv(p["xattn"], ctx.cross_src, cfg)
            if mode == "prefill":
                new_cache.update({"ck": ck, "cv": cv})
        y = A.cross_attention_block(p["xattn"], h, cfg, ck, cv)
        x = x + y
    else:  # self_attn / moe / dense / encoder / local_attn
        if mode == "decode":
            y, nk, nv = A.attention_block_decode(
                p["attn"], h, cfg, ctx.pos, cache["k"], cache["v"], window=window
            )
            new_cache = {"k": nk, "v": nv}
        else:
            causal = kind != "encoder"
            if mode == "prefill" and kind != "encoder":
                y, kc, vc = A.attention_block(
                    p["attn"], h, cfg, ctx.positions, window=window,
                    causal=causal, return_kv=True,
                )
                new_cache = {"k": kc, "v": vc}
            else:
                y = A.attention_block(
                    p["attn"], h, cfg, ctx.positions, window=window, causal=causal
                )
        x = x + y

    x = _constrain(x, cfg, pctx)

    # ---- FFN sublayer ------------------------------------------------------
    if kind == "moe":
        h = norm("ln2", x)
        y, aux = M.apply_moe(p["moe"], h, cfg, pctx)
        x = x + y
    elif kind != "ssm":
        h = norm("ln2", x)
        y = F.apply_ffn(p["ffn"], h, cfg)
        x = x + y
    return _constrain(x, cfg, pctx), aux, new_cache


# --------------------------------------------------------------------------
# stack init / apply
# --------------------------------------------------------------------------


def init_stack(key, cfg: ModelConfig, plan: StackPlan) -> Dict:
    ks = jax.random.split(key, 3)
    out: Dict[str, Any] = {}
    if plan.prefix:
        keys = jax.random.split(ks[0], len(plan.prefix))
        out["prefix"] = [
            init_layer(keys[i], cfg, k) for i, k in enumerate(plan.prefix)
        ]
    if plan.n_scan:
        blocks = {}
        pkeys = jax.random.split(ks[1], len(plan.pattern))
        for i, kind in enumerate(plan.pattern):
            lkeys = jax.random.split(pkeys[i], plan.n_scan)
            blocks[str(i)] = jax.vmap(
                lambda kk, kind=kind: init_layer(kk, cfg, kind)
            )(lkeys)
        out["blocks"] = blocks
    if plan.tail:
        keys = jax.random.split(ks[2], len(plan.tail))
        out["tail"] = [
            init_layer(keys[i], cfg, k) for i, k in enumerate(plan.tail)
        ]
    return out


def apply_stack(
    params: Dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    pctx: ParallelContext,
    ctx: LayerCtx,
    plan: StackPlan,
    caches: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict]]:
    """Run prefix -> scanned superblocks -> tail.

    Returns (x, total_aux, new_caches); new_caches is None in train mode.
    """
    aux_total = jnp.zeros((), jnp.float32)
    want_cache = ctx.mode in ("prefill", "decode")
    new_caches: Dict[str, Any] = {"prefix": [], "tail": []} if want_cache else None

    for i, kind in enumerate(plan.prefix):
        c = caches["prefix"][i] if caches else None
        x, aux, nc = apply_layer(kind, params["prefix"][i], x, cfg, pctx, ctx, c)
        aux_total = aux_total + aux
        if want_cache:
            new_caches["prefix"].append(nc)

    if plan.n_scan:
        pat = plan.pattern

        def block_body(carry, xs):
            h, aux_acc = carry
            bp = xs[0]
            bc = xs[1] if len(xs) > 1 else None
            ncs = {}
            for i, kind in enumerate(pat):
                c = bc[str(i)] if bc is not None else None
                h, aux, nc = apply_layer(kind, bp[str(i)], h, cfg, pctx, ctx, c)
                aux_acc = aux_acc + aux
                if nc is not None:
                    ncs[str(i)] = nc
            return (h, aux_acc), (ncs if ncs else 0)

        body = block_body
        if ctx.mode == "train" and cfg.remat == "full":
            body = jax.checkpoint(
                block_body, policy=jax.checkpoint_policies.nothing_saveable
            )
        xs = (params["blocks"],)
        if caches is not None:
            xs = (params["blocks"], caches["blocks"])
        (x, aux_total), ys = lax.scan(body, (x, aux_total), xs)
        if want_cache:
            new_caches["blocks"] = ys

    for i, kind in enumerate(plan.tail):
        c = caches["tail"][i] if caches else None
        x, aux, nc = apply_layer(kind, params["tail"][i], x, cfg, pctx, ctx, c)
        aux_total = aux_total + aux
        if want_cache:
            new_caches["tail"].append(nc)

    return x, aux_total, new_caches
