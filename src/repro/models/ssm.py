"""Mamba-1 selective-state-space block (falcon-mamba-7b).

Train/prefill uses a *chunked* selective scan: the sequence is processed
in chunks of `chunk` steps; within a chunk the diagonal recurrence is
solved with an associative scan, and a single (B, d_inner, N) state is
carried between chunks.  This keeps the materialized discretized tensors
to (B, chunk, d_inner, N) — the same blocking the Pallas kernel
(kernels/mamba_scan) uses on TPU VMEM — instead of the naive
(B, S, d_inner, N) which is petabytes at the 500k design points.

Decode carries (conv_state, ssm_state) and is O(1) in context length.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import apply_causal_conv, dense_init, init_causal_conv


def init_mamba(key, cfg: ModelConfig) -> Dict:
    s = cfg.ssm
    D, Di, N, R = cfg.d_model, cfg.d_inner_, s.state_dim, cfg.dt_rank_
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    # S4D-real init for A
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (Di, 1))
    dt_bias = jnp.log(jnp.expm1(jnp.exp(
        jax.random.uniform(ks[5], (Di,), minval=jnp.log(1e-3), maxval=jnp.log(1e-1))
    )))
    return {
        "in_proj": dense_init(ks[0], D, 2 * Di, dt),
        "conv": init_causal_conv(ks[1], Di, s.conv_kernel, dt),
        "x_proj": dense_init(ks[2], Di, R + 2 * N, dt),
        "dt_proj": dense_init(ks[3], R, Di, dt),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((Di,), jnp.float32),
        "out_proj": dense_init(ks[4], Di, D, dt),
    }


def _ssm_params(p, x, cfg: ModelConfig):
    """dt (B,T,Di), Bmat (B,T,N), Cmat (B,T,N) from the conv output x."""
    s = cfg.ssm
    R, N = cfg.dt_rank_, s.state_dim
    dbc = x @ p["x_proj"].astype(x.dtype)
    dt_r, Bm, Cm = jnp.split(dbc, [R, R + N], axis=-1)
    dt = dt_r @ p["dt_proj"].astype(x.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _scan_chunk(h0, dA, dBx, Cm):
    """Associative scan of h_t = dA_t * h_{t-1} + dBx_t within a chunk.

    h0: (B, Di, N); dA, dBx: (B, T, Di, N); Cm: (B, T, N).
    Returns (y (B,T,Di), h_T).
    """

    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, b1 * a2 + b2

    # fold the carried state into the first step
    dBx = dBx.at[:, 0].add(dA[:, 0] * h0)
    aA, hs = lax.associative_scan(combine, (dA, dBx), axis=1)
    y = jnp.einsum("btdn,btn->btd", hs, Cm)
    return y, hs[:, -1]


def mamba_mix(
    p: Dict, u: jnp.ndarray, cfg: ModelConfig, chunk: int = 256,
    return_state: bool = False,
):
    """Full-sequence mixer (train / prefill).  u: (B, S, D).

    With return_state=True also returns (conv_state, ssm_state) for decode
    continuation.
    """
    s = cfg.ssm
    Di, N = cfg.d_inner_, s.state_dim
    B, S, D = u.shape
    xz = u @ p["in_proj"].astype(u.dtype)
    x_pre, z = jnp.split(xz, 2, axis=-1)
    x, _ = apply_causal_conv(p["conv"], x_pre)
    x = jax.nn.silu(x)

    A = -jnp.exp(p["A_log"])  # (Di, N)
    T = min(chunk, S)
    while S % T:
        T -= 1
    nchunks = S // T

    xc = x.reshape(B, nchunks, T, Di).transpose(1, 0, 2, 3)
    h0 = jnp.zeros((B, Di, N), jnp.float32)

    def per_chunk(h, xcp):
        dt, Bm, Cm = _ssm_params(p, xcp, cfg)           # (B,T,Di),(B,T,N)
        dA = jnp.exp(dt[..., None] * A)                 # (B,T,Di,N)
        dBx = (dt * xcp.astype(jnp.float32))[..., None] * Bm[:, :, None, :]
        y, h_new = _scan_chunk(h, dA, dBx, Cm)
        return h_new, y

    h_last, ys = lax.scan(per_chunk, h0, xc)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, Di)
    y = y + x.astype(jnp.float32) * p["D"]
    y = y.astype(u.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(u.dtype)
    if return_state:
        K = s.conv_kernel
        conv_state = x_pre[:, -(K - 1):, :]
        return out, conv_state, h_last
    return out


def mamba_decode(
    p: Dict,
    u: jnp.ndarray,            # (B, 1, D)
    cfg: ModelConfig,
    conv_state: jnp.ndarray,   # (B, K-1, Di)
    ssm_state: jnp.ndarray,    # (B, Di, N)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-token step; O(1) in context length."""
    xz = u @ p["in_proj"].astype(u.dtype)
    x, z = jnp.split(xz, 2, axis=-1)
    x, conv_state = apply_causal_conv(p["conv"], x, conv_state)
    x = jax.nn.silu(x)
    dt, Bm, Cm = _ssm_params(p, x, cfg)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[:, 0, :, None] * A)                       # (B,Di,N)
    dBx = (dt[:, 0] * x[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    h = dA * ssm_state + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])
    y = y + x[:, 0].astype(jnp.float32) * p["D"]
    y = (y[:, None].astype(u.dtype)) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(u.dtype), conv_state, h
