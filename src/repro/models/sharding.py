"""Parameter / activation / cache PartitionSpec rules.

FSDP(+TP) layout: every weight matrix is sharded along `model` (TP) on
its "parallel" dimension and along the data axes on the other (ZeRO-3
analog).  Rules are name+shape based with divisibility fallbacks (a dim
that doesn't divide the axis size stays replicated on that axis), so the
same rule-tree serves all 10 architectures, meshes of any size, and both
the fp32 master params and the optimizer moments.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.parallel import ParallelContext

# rule: param-name -> (spec for the trailing ndim dims, rightmost aligned)
# "tp" = model axis, "dp" = fsdp over data axes, None = replicated
_MATRIX_RULES: Dict[str, Tuple[str, ...]] = {
    # embeddings / head: vocab on tp (column-parallel head, row-gathered embed)
    "embed": ("tp", "dp"),
    "lm_head": ("dp", "tp"),
    # attention
    "wq": ("dp", "tp"),
    "wk": ("dp", "tp"),
    "wv": ("dp", "tp"),
    "wo": ("tp", "dp"),
    # dense ffn
    "w_gate": ("dp", "tp"),
    "w_up": ("dp", "tp"),
    "w_down": ("tp", "dp"),
    "w_in": ("dp", "tp"),
    "w_out": ("tp", "dp"),
    # moe experts (leading expert dim handled specially: experts on tp)
    "router": ("dp", None),
    "shared_gate": ("dp", "tp"),
    "shared_up": ("dp", "tp"),
    "shared_down": ("tp", "dp"),
    # mamba
    "in_proj": ("dp", "tp"),
    "x_proj": ("tp", None),
    "dt_proj": (None, "tp"),
    "out_proj": ("tp", "dp"),
    "A_log": ("tp", None),
    # rg-lru
    "w_y": ("dp", "tp"),
    "w_x": ("dp", "tp"),
    "w_a": ("tp", None),
    "w_i": ("tp", None),
    "w_out_rec": ("tp", "dp"),
}

_EXPERT_LEAVES = ("w_gate", "w_up", "w_down")


def _axis_ok(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def param_spec(
    path: Tuple[Any, ...],
    shape: Tuple[int, ...],
    cfg: ModelConfig,
    pctx: ParallelContext,
) -> P:
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = keys[-1]
    tp, tp_n = pctx.tp_axis, pctx.tp_size
    dp = tuple(pctx.dp_axes)
    dp_n = pctx.dp_size if pctx.fsdp_params else 1
    in_moe = "moe" in keys
    ndim = len(shape)

    def resolve(kindseq, dims):
        out = []
        for kind, d in zip(kindseq, dims):
            if kind == "tp" and _axis_ok(d, tp_n):
                out.append(tp)
            elif kind == "dp" and _axis_ok(d, dp_n):
                out.append(dp)
            else:
                out.append(None)
        return out

    if in_moe and name in _EXPERT_LEAVES:
        # stacked experts: (..., E, D, F) -> experts over tp, F/D over dp
        lead = [None] * (ndim - 3)
        e_dim = shape[-3]
        spec = lead + resolve(
            ("tp", "dp", None), (e_dim, shape[-2], shape[-1])
        )
        return P(*spec)

    # rg-lru final projection shares the "w_out" name with plain mlps;
    # disambiguate by parent
    rule_name = name
    if name == "w_out" and "rec" in keys:
        rule_name = "w_out_rec"

    rule = _MATRIX_RULES.get(rule_name)
    if rule is None or ndim < 2:
        # biases / norms / scalars: shard the last dim over tp if large
        if ndim == 1 and _axis_ok(shape[0], tp_n) and shape[0] >= 4096:
            return P(*([None] * (ndim - 1) + [tp]))
        return P(*([None] * ndim))
    lead = [None] * (ndim - 2)
    spec = lead + resolve(rule, shape[-2:])
    # avoid double-booking an axis (can't appear twice in one spec)
    return P(*spec)


def param_shardings(shapes, cfg: ModelConfig, pctx: ParallelContext):
    mesh = pctx.mesh

    def one(path, leaf):
        return NamedSharding(mesh, param_spec(path, leaf.shape, cfg, pctx))

    return jax.tree_util.tree_map_with_path(one, shapes)


# ---------------- batch / cache shardings ----------------------------------


def batch_spec(name: str, shape: Tuple[int, ...], pctx: ParallelContext) -> P:
    dp = tuple(pctx.dp_axes)
    B = shape[0]
    if not _axis_ok(B, pctx.dp_size):
        return P(*([None] * len(shape)))
    return P(dp, *([None] * (len(shape) - 1)))


def batch_shardings(specs: Dict[str, Any], pctx: ParallelContext):
    mesh = pctx.mesh
    return {
        k: jax.ShapeDtypeStruct(
            v.shape,
            v.dtype,
            sharding=NamedSharding(mesh, batch_spec(k, v.shape, pctx)),
        )
        for k, v in specs.items()
    }


def cache_spec(path, shape: Tuple[int, ...], pctx: ParallelContext) -> P:
    """KV caches: batch over dp, sequence (axis -2 for k/v, len>=1024) over
    tp — flash-decoding style sequence parallelism for the 32k caches."""
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = keys[-1]
    dp = tuple(pctx.dp_axes)
    spec: list = [None] * len(shape)
    # caches are (B, ...) per layer or (n_scan, B, ...) when stacked;
    # locate the batch dim from the base rank of each state kind
    base_rank = {"k": 4, "v": 4, "ck": 4, "cv": 4, "conv": 3, "ssm": 3, "lru": 2}
    bdim = len(shape) - base_rank.get(name, len(shape))
    if 0 <= bdim < len(shape) and _axis_ok(shape[bdim], pctx.dp_size):
        spec[bdim] = dp
    if name in ("k", "v", "ck", "cv"):
        sdim = len(shape) - 2
        if _axis_ok(shape[sdim], pctx.tp_size) and shape[sdim] >= 1024:
            spec[sdim] = pctx.tp_axis
        elif _axis_ok(shape[len(shape) - 3], pctx.tp_size):
            spec[len(shape) - 3] = pctx.tp_axis  # kv heads over tp
    elif name in ("conv", "ssm"):
        # channel dim over tp
        cdim = len(shape) - 1 if name == "conv" else len(shape) - 2
        if _axis_ok(shape[cdim], pctx.tp_size):
            spec[cdim] = pctx.tp_axis
    elif name == "lru":
        if _axis_ok(shape[-1], pctx.tp_size):
            spec[-1] = pctx.tp_axis
    return P(*spec)


def cache_shardings(cache_tree, pctx: ParallelContext):
    mesh = pctx.mesh

    def one(path, leaf):
        return jax.ShapeDtypeStruct(
            leaf.shape,
            leaf.dtype,
            sharding=NamedSharding(mesh, cache_spec(path, leaf.shape, pctx)),
        )

    return jax.tree_util.tree_map_with_path(one, cache_tree)
