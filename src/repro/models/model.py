"""Public model API: init, shapes, forwards (train / prefill / decode), loss.

All entry points are pure functions of (params, batch) suitable for
jax.jit with NamedSharding in/out specs, or for eval_shape-based dry-runs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import transformer as T
from repro.models.kvcache import cache_specs, init_cache
from repro.models.layers import apply_norm, dense_init, embed_init, init_norm
from repro.models.parallel import ParallelContext


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Dict:
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.param_dtype)
    plan = T.stack_plan(cfg)
    p: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "stack": T.init_stack(ks[1], cfg, plan),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_size, dt)
    if cfg.family == "encdec":
        p["encoder"] = T.init_stack(ks[3], cfg, T.encoder_plan(cfg))
        p["enc_norm"] = init_norm(cfg.norm, cfg.d_model, dt)
    return p


def param_shapes(cfg: ModelConfig) -> Dict:
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.key(0)
    )


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = param_shapes(cfg)
    total = 0
    m = cfg.moe

    def visit(path, leaf):
        nonlocal total
        n = int(np.prod(leaf.shape))
        if active_only and m is not None:
            keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
            if any("moe" == k for k in keys) and any(
                k in ("w_gate", "w_up", "w_down") for k in keys
            ):
                if m.num_experts in leaf.shape:
                    n = int(n * m.top_k / m.num_experts)
        total += n

    jax.tree_util.tree_map_with_path(visit, shapes)
    return total


# --------------------------------------------------------------------------
# forwards
# --------------------------------------------------------------------------


def _embed(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    return x.astype(jnp.dtype(cfg.compute_dtype))


def _logits(params, x, cfg: ModelConfig):
    x = apply_norm(cfg.norm, params["final_norm"], x, upcast=cfg.norm_upcast)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    )
    return x.astype(jnp.float32) @ head.astype(jnp.float32)


def _encode(params, encoder_embeds, cfg, pctx):
    S = encoder_embeds.shape[1]
    ctx = T.LayerCtx(
        positions=jnp.arange(S, dtype=jnp.int32), mode="train"
    )
    x, _, _ = T.apply_stack(
        params["encoder"], encoder_embeds, cfg, pctx, ctx, T.encoder_plan(cfg)
    )
    return apply_norm(cfg.norm, params["enc_norm"], x, upcast=cfg.norm_upcast)


def forward_train(
    params: Dict,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    pctx: ParallelContext,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits (B,S,V) fp32, aux_loss)."""
    tokens = batch["tokens"]
    S = tokens.shape[1]
    cross_src = None
    if cfg.family == "encdec":
        cross_src = _encode(params, batch["encoder_embeds"], cfg, pctx)
    elif cfg.family == "vlm":
        cross_src = batch["image_embeds"]
    x = _embed(params, tokens, cfg)
    ctx = T.LayerCtx(
        positions=jnp.arange(S, dtype=jnp.int32),
        cross_src=cross_src,
        mode="train",
    )
    x, aux, _ = T.apply_stack(
        params["stack"], x, cfg, pctx, ctx, T.stack_plan(cfg)
    )
    return _logits(params, x, cfg), aux


def forward_prefill(
    params: Dict,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    pctx: ParallelContext,
    cache_len: Optional[int] = None,
):
    """Returns (last-token logits (B,V), decode caches).

    With cache_len, self-attention K/V caches are padded to that length so
    decode steps have slots to write into (ring-buffer window caches are
    already sized to their window and are left alone).
    """
    tokens = batch["tokens"]
    S = tokens.shape[1]
    cross_src = None
    if cfg.family == "encdec":
        cross_src = _encode(params, batch["encoder_embeds"], cfg, pctx)
    elif cfg.family == "vlm":
        cross_src = batch["image_embeds"]
    x = _embed(params, tokens, cfg)
    ctx = T.LayerCtx(
        positions=jnp.arange(S, dtype=jnp.int32),
        cross_src=cross_src,
        mode="prefill",
    )
    x, _, caches = T.apply_stack(
        params["stack"], x, cfg, pctx, ctx, T.stack_plan(cfg)
    )
    if cache_len is not None and cache_len > S:
        window = cfg.hybrid.local_window if cfg.hybrid else 0

        def pad(path, leaf):
            name = str(getattr(path[-1], "key", ""))
            if name not in ("k", "v") or leaf.ndim < 4:
                return leaf
            sdim = leaf.ndim - 2
            cur = leaf.shape[sdim]
            if cur != S:
                return leaf  # ring cache already at its window size
            tgt = min(cache_len, window) if window else cache_len
            if tgt <= cur:
                return leaf
            pads = [(0, 0)] * leaf.ndim
            pads[sdim] = (0, tgt - cur)
            return jnp.pad(leaf, pads)

        caches = jax.tree_util.tree_map_with_path(pad, caches)
    return _logits(params, x[:, -1:], cfg)[:, 0], caches


def forward_decode(
    params: Dict,
    tokens: jnp.ndarray,        # (B, 1)
    positions: jnp.ndarray,     # (B,)
    caches,
    cfg: ModelConfig,
    pctx: ParallelContext,
):
    """One decode step.  Returns (logits (B,V), new caches)."""
    x = _embed(params, tokens, cfg)
    ctx = T.LayerCtx(pos=positions, mode="decode")
    x, _, new_caches = T.apply_stack(
        params["stack"], x, cfg, pctx, ctx, T.stack_plan(cfg), caches=caches
    )
    return _logits(params, x, cfg)[:, 0], new_caches


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------


def softmax_xent(logits: jnp.ndarray, targets: jnp.ndarray, z_weight=1e-4):
    """Mean token cross-entropy (+ z-loss) in fp32."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = (lse - gold).mean()
    z = (lse**2).mean() * z_weight
    return ce + z, ce


def _pick_chunk(v: int, target: int) -> int:
    c = min(target, v)
    while v % c:
        c -= 1
    return max(c, 1)


def softmax_xent_chunked(
    x: jnp.ndarray,        # (B, S, D) final normed hidden
    head: jnp.ndarray,     # (D, V)
    targets: jnp.ndarray,  # (B, S)
    chunk: int,
    z_weight=1e-4,
):
    """Vocab-chunked CE: the (B, S, V) logits are never materialized.

    Online logsumexp over vocab chunks inside a rematerialized scan — the
    classic memory-roofline optimization for large-vocab losses (§Perf).
    """
    D, V = head.shape
    c = _pick_chunk(V, chunk)
    nc = V // c
    x32 = x.astype(jnp.float32)
    hc = head.astype(jnp.float32).reshape(D, nc, c).transpose(1, 0, 2)
    los = jnp.arange(nc) * c

    @jax.checkpoint
    def body(carry, xs):
        m, s, gold = carry
        h, lo = xs
        logits = x32 @ h                                    # (B, S, c)
        m_new = jnp.maximum(m, logits.max(-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(
            logits - m_new[..., None]
        ).sum(-1)
        t_loc = targets - lo
        in_chunk = (t_loc >= 0) & (t_loc < c)
        g = jnp.take_along_axis(
            logits, jnp.clip(t_loc, 0, c - 1)[..., None], axis=-1
        )[..., 0]
        gold = gold + jnp.where(in_chunk, g, 0.0)
        return (m_new, s, gold), None

    B, S = targets.shape
    m0 = jnp.full((B, S), -1e30, jnp.float32)
    s0 = jnp.zeros((B, S), jnp.float32)
    g0 = jnp.zeros((B, S), jnp.float32)
    (m, s, gold), _ = jax.lax.scan(body, (m0, s0, g0), (hc, los))
    lse = m + jnp.log(jnp.maximum(s, 1e-30))
    ce = (lse - gold).mean()
    z = (lse**2).mean() * z_weight
    return ce + z, ce


def forward_train_hidden(params, batch, cfg: ModelConfig, pctx):
    """Like forward_train but stops before the LM head (chunked loss)."""
    tokens = batch["tokens"]
    S = tokens.shape[1]
    cross_src = None
    if cfg.family == "encdec":
        cross_src = _encode(params, batch["encoder_embeds"], cfg, pctx)
    elif cfg.family == "vlm":
        cross_src = batch["image_embeds"]
    x = _embed(params, tokens, cfg)
    ctx = T.LayerCtx(
        positions=jnp.arange(S, dtype=jnp.int32),
        cross_src=cross_src,
        mode="train",
    )
    x, aux, _ = T.apply_stack(
        params["stack"], x, cfg, pctx, ctx, T.stack_plan(cfg)
    )
    return apply_norm(cfg.norm, params["final_norm"], x,
                      upcast=cfg.norm_upcast), aux


def loss_fn(
    params: Dict,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    pctx: ParallelContext,
):
    if cfg.loss_chunk_vocab:
        x, aux = forward_train_hidden(params, batch, cfg, pctx)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        total, ce = softmax_xent_chunked(
            x, head, batch["targets"], cfg.loss_chunk_vocab
        )
    else:
        logits, aux = forward_train(params, batch, cfg, pctx)
        total, ce = softmax_xent(logits, batch["targets"])
    if cfg.moe is not None:
        total = total + cfg.moe.router_aux_weight * aux
    return total, {"loss": ce, "aux": aux, "total": total}
