"""Dense feed-forward blocks: SwiGLU / GeGLU (gated) and plain MLP."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import act_fn, dense_init


def gated(cfg: ModelConfig) -> bool:
    return cfg.act in ("silu", "gelu")


def init_ffn(key, cfg: ModelConfig, d_ff: int | None = None) -> Dict:
    d_ff = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if gated(cfg):
        return {
            "w_gate": dense_init(ks[0], cfg.d_model, d_ff, dt),
            "w_up": dense_init(ks[1], cfg.d_model, d_ff, dt),
            "w_down": dense_init(ks[2], d_ff, cfg.d_model, dt),
        }
    return {
        "w_in": dense_init(ks[0], cfg.d_model, d_ff, dt),
        "b_in": jnp.zeros((d_ff,), dt),
        "w_out": dense_init(ks[1], d_ff, cfg.d_model, dt),
        "b_out": jnp.zeros((cfg.d_model,), dt),
    }


def apply_ffn(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    f = act_fn(cfg.act)
    if "w_gate" in p:
        g = f(x @ p["w_gate"].astype(x.dtype))
        u = x @ p["w_up"].astype(x.dtype)
        return (g * u) @ p["w_down"].astype(x.dtype)
    h = f(x @ p["w_in"].astype(x.dtype) + p["b_in"].astype(x.dtype))
    return h @ p["w_out"].astype(x.dtype) + p["b_out"].astype(x.dtype)
