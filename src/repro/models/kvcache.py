"""Decode-state management: KV caches, SSM/LRU states, cross-attn caches.

Cache pytrees mirror the stack plan of the model (prefix / scanned blocks
/ tail) so they can be threaded through `lax.scan` alongside the stacked
layer params.  `cache_specs` builds ShapeDtypeStruct stand-ins for the
dry-run (decode cells lower `serve_step` against a standing cache of
`seq_len`, per the assignment).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def layer_cache_shape(cfg: ModelConfig, kind: str, B: int, L: int) -> Dict:
    hd = cfg.head_dim_
    Hkv = cfg.num_kv_heads
    cd = jnp.dtype(cfg.compute_dtype)
    if kind in ("self_attn", "moe", "dense"):
        return {"k": ((B, Hkv, L, hd), cd), "v": ((B, Hkv, L, hd), cd)}
    if kind == "local_attn":
        W = min(cfg.hybrid.local_window, L)
        return {"k": ((B, Hkv, W, hd), cd), "v": ((B, Hkv, W, hd), cd)}
    if kind == "decoder":
        Lx = L  # encoder length == decoder context in our shape cells
        return {
            "k": ((B, Hkv, L, hd), cd),
            "v": ((B, Hkv, L, hd), cd),
            "ck": ((B, Hkv, Lx, hd), cd),
            "cv": ((B, Hkv, Lx, hd), cd),
        }
    if kind == "cross_attn":
        n = cfg.num_image_tokens
        return {"ck": ((B, Hkv, n, hd), cd), "cv": ((B, Hkv, n, hd), cd)}
    if kind == "ssm":
        s = cfg.ssm
        Di = cfg.d_inner_
        return {
            "conv": ((B, s.conv_kernel - 1, Di), cd),
            "ssm": ((B, Di, s.state_dim), jnp.dtype(jnp.float32)),
        }
    if kind == "rglru":
        Dl = cfg.lru_width_
        return {
            "conv": ((B, 3, Dl), cd),
            "lru": ((B, Dl), jnp.dtype(jnp.float32)),
        }
    raise ValueError(kind)


def _make(entry, builder):
    return {k: builder(shape, dt) for k, (shape, dt) in entry.items()}


def _build_tree(cfg: ModelConfig, B: int, L: int, builder):
    from repro.models.transformer import stack_plan

    plan = stack_plan(cfg)
    tree: Dict[str, Any] = {}
    tree["prefix"] = [
        _make(layer_cache_shape(cfg, k, B, L), builder) for k in plan.prefix
    ]
    if plan.n_scan:
        blocks = {}
        for i, kind in enumerate(plan.pattern):
            entry = layer_cache_shape(cfg, kind, B, L)
            blocks[str(i)] = {
                k: builder((plan.n_scan,) + shape, dt)
                for k, (shape, dt) in entry.items()
            }
        tree["blocks"] = blocks
    tree["tail"] = [
        _make(layer_cache_shape(cfg, k, B, L), builder) for k in plan.tail
    ]
    return tree


def init_cache(cfg: ModelConfig, B: int, L: int):
    """Zero-filled decode state (used by tests / serving)."""
    return _build_tree(cfg, B, L, lambda s, dt: jnp.zeros(s, dt))


def cache_specs(cfg: ModelConfig, B: int, L: int):
    """ShapeDtypeStruct stand-ins (dry-run, no allocation)."""
    return _build_tree(cfg, B, L, lambda s, dt: jax.ShapeDtypeStruct(s, dt))
