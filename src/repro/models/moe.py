"""Mixture-of-Experts with Opera-scheduled expert-parallel dispatch.

Experts are sharded over the `model` (TP) mesh axis; tokens are sharded
over data (batch) and, for train/prefill, over `model` (sequence).  The
dispatch/combine all-to-all along the expert axis is *exactly* the
paper's bulk shuffle: per-destination buffers queued at the source and
delivered on direct one-hop circuits.  `moe_dispatch` selects:

    rotor      — rotor_all_to_all (one-hop direct schedule, zero tax)
    rotor_vlb  — RotorLB 2-hop Valiant spreading (skew-proof, 100 % tax)
    xla        — lax.all_to_all baseline
    local      — no a2a (decode / replicated-activation path)

Routing is capacity-based (deterministic drop, GShard-style) so that all
buffer shapes are static — the "pre-configured matchings, no runtime
circuit selection" property of Opera carried into the collective layer.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.core import collectives as C
from repro.models.layers import act_fn, dense_init
from repro.models.parallel import ParallelContext


# ---------------- params ---------------------------------------------------


def init_moe(key, cfg: ModelConfig) -> Dict:
    m = cfg.moe
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    E, D, F = m.num_experts, cfg.d_model, m.d_ff_expert
    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),  # fp32 router
        "w_gate": jax.vmap(lambda k: dense_init(k, D, F, dt))(
            jax.random.split(ks[1], E)
        ),
        "w_up": jax.vmap(lambda k: dense_init(k, D, F, dt))(
            jax.random.split(ks[2], E)
        ),
        "w_down": jax.vmap(lambda k: dense_init(k, F, D, dt))(
            jax.random.split(ks[3], E)
        ),
    }
    if m.num_shared_experts:
        Fs = m.d_ff_shared
        p["shared_gate"] = dense_init(ks[4], D, Fs, dt)
        p["shared_up"] = dense_init(ks[5], D, Fs, dt)
        p["shared_down"] = dense_init(ks[6], Fs, D, dt)
    return p


# ---------------- routing helpers (per-shard, pure jnp) ---------------------


def _topk_route(logits: jnp.ndarray, k: int):
    """softmax -> top-k -> renormalize (Qwen3/DeepSeek norm_topk_prob)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # (T, E)
    gates, idx = lax.top_k(probs, k)                              # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def _rank_within_expert(e_flat: jnp.ndarray, E: int) -> jnp.ndarray:
    """rank[i] = #earlier slots assigned to the same expert (stable)."""
    Tk = e_flat.shape[0]
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    rank_sorted = jnp.arange(Tk) - starts[sorted_e]
    rank = jnp.zeros((Tk,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    return rank


def _dispatch_combine_local(
    x_tok: jnp.ndarray,  # (T, D)
    gates: jnp.ndarray,  # (T, k)
    idx: jnp.ndarray,    # (T, k)
    wg, wu, wd,          # (E_loc, D, F), ..., (E_loc, F, D)
    cfg: ModelConfig,
    capacity: int,
    a2a=None,            # callable (n, E_loc, C, D)->same, or None for local
    n_shards: int = 1,
    expert_offset: Optional[jnp.ndarray] = None,
):
    """Capacity-dispatch, (optional) a2a, per-expert FFN, combine."""
    m = cfg.moe
    E = m.num_experts
    T, D = x_tok.shape
    k = idx.shape[1]
    f = act_fn(cfg.act)

    e_flat = idx.reshape(-1)
    g_flat = gates.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(T), k)
    rank = _rank_within_expert(e_flat, E)
    keep = rank < capacity
    slot = jnp.where(keep, e_flat * capacity + rank, E * capacity)

    buf = jnp.zeros((E * capacity + 1, D), x_tok.dtype)
    buf = buf.at[slot].set(x_tok[t_flat])
    buf = buf[:-1].reshape(E, capacity, D)

    if a2a is not None:
        E_loc = E // n_shards
        sent = a2a(buf.reshape(n_shards, E_loc, capacity, D))
        # sent[s] = buffer from source shard s for MY experts
        h = sent.transpose(1, 0, 2, 3).reshape(E_loc, n_shards * capacity, D)
    else:
        E_loc = wg.shape[0]
        if E_loc != E:
            # local mode with sharded experts: select my experts' buffers
            # expert_offset = E_loc * my_shard_index (traced)
            h = lax.dynamic_slice_in_dim(buf, expert_offset, E_loc, axis=0)
        else:
            h = buf

    # per-expert gated FFN (grouped GEMM; kernels/moe_gmm mirrors this)
    ge = jnp.einsum("ecd,edf->ecf", h, wg.astype(h.dtype))
    up = jnp.einsum("ecd,edf->ecf", h, wu.astype(h.dtype))
    out = jnp.einsum("ecf,efd->ecd", f(ge) * up, wd.astype(h.dtype))

    if a2a is not None:
        back = a2a(
            out.reshape(E_loc, n_shards, capacity, D).transpose(1, 0, 2, 3)
        )
        # back[s] = my tokens' outputs from expert shard s
        out_full = back.reshape(E, capacity, D)
    else:
        if E_loc != E:
            out_full = jnp.zeros((E, capacity, D), out.dtype)
            out_full = lax.dynamic_update_slice_in_dim(
                out_full, out, expert_offset, axis=0
            )
        else:
            out_full = out

    flat = jnp.concatenate(
        [out_full.reshape(E * capacity, D), jnp.zeros((1, D), out.dtype)], axis=0
    )
    y_slots = flat[slot] * (g_flat * keep)[:, None].astype(out.dtype)
    y = jnp.zeros((T, D), out.dtype).at[t_flat].add(y_slots)
    return y


def _aux_loss(probs: jnp.ndarray, idx: jnp.ndarray, E: int) -> jnp.ndarray:
    """Switch-style load-balance loss: E * sum_e f_e * P_e (local view;
    globally averaged by the caller over the latency path)."""
    T, k = idx.shape
    f_e = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
    P_e = probs.mean(axis=0)
    return E * jnp.sum(f_e * P_e)


# ---------------- public apply ----------------------------------------------


def apply_moe(
    p: Dict,
    x: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
    pctx: ParallelContext,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss).  Routed experts + optional shared branch."""
    m = cfg.moe
    B, S, D = x.shape
    E, k = m.num_experts, m.top_k

    # shared/always-on branch (DeepSeekMoE)
    shared = 0.0
    if m.num_shared_experts:
        f = act_fn(cfg.act)
        g = f(x @ p["shared_gate"].astype(x.dtype))
        u = x @ p["shared_up"].astype(x.dtype)
        shared = (g * u) @ p["shared_down"].astype(x.dtype)

    tp = pctx.tp_size
    use_a2a = tp > 1 and S % tp == 0 and S > 1

    if pctx.mesh is None or tp == 1:
        # single-shard path (smoke tests): no communication
        T = B * S
        capacity = _capacity(T, k, E, m.capacity_factor)
        logits = x.reshape(T, D).astype(jnp.float32) @ p["router"]
        gates, idx, probs = _topk_route(logits, k)
        y = _dispatch_combine_local(
            x.reshape(T, D), gates, idx,
            p["w_gate"], p["w_up"], p["w_down"], cfg, capacity,
        ).reshape(B, S, D)
        return y + shared, _aux_loss(probs, idx, E)

    # NOTE: shard_map uses the AMBIENT mesh (jax.set_mesh / enclosing
    # shard_map) so the MoE dispatch nests inside the pod-manual rotor
    # gradient-sync region (trainer.py) without a concrete/abstract clash.
    dp = tuple(pctx.dp_axes)
    tp_axis = pctx.tp_axis
    E_loc = E // tp

    def a2a_fn(buf):  # (tp, E_loc, C, D) per shard
        if pctx.moe_dispatch == "xla":
            return lax.all_to_all(buf, tp_axis, split_axis=0, concat_axis=0,
                                  tiled=True)
        return C.rotor_all_to_all(
            buf, tp_axis, vlb=(pctx.moe_dispatch == "rotor_vlb")
        )

    if use_a2a:
        in_spec = P(dp, tp_axis, None)     # tokens sharded over dp x seq/tp

        def shard_fn(xl, router, wg, wu, wd):
            b, s, _ = xl.shape
            T = b * s
            capacity = _capacity(T, k, E, m.capacity_factor)
            logits = xl.reshape(T, D).astype(jnp.float32) @ router
            gates, idx, probs = _topk_route(logits, k)
            y = _dispatch_combine_local(
                xl.reshape(T, D), gates, idx, wg, wu, wd, cfg, capacity,
                a2a=a2a_fn, n_shards=tp,
            ).reshape(b, s, D)
            # aux loss: global mean via the latency-class expander path
            aux = _aux_loss(probs, idx, E)
            aux = C.expander_psum_latency(aux[None], tp_axis)[0]
            for ax in dp[::-1]:
                aux = C.expander_psum_latency(aux[None], ax)[0]
            aux = aux / (tp * pctx.dp_size)
            return y, aux

        y, aux = shard_map(
            shard_fn,
            in_specs=(in_spec, P(), P(tp_axis, None, None),
                      P(tp_axis, None, None), P(tp_axis, None, None)),
            out_specs=(in_spec, P()),
            check_vma=False,
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    else:
        # decode path: tokens replicated over tp; each shard runs its local
        # experts only, partial outputs summed over tp (rotor-direct).
        in_spec = P(dp, None, None)

        def shard_fn(xl, router, wg, wu, wd):
            b, s, _ = xl.shape
            T = b * s
            capacity = _capacity(T, k, E, m.capacity_factor)
            logits = xl.reshape(T, D).astype(jnp.float32) @ router
            gates, idx, probs = _topk_route(logits, k)
            off = (lax.axis_index(tp_axis) * E_loc).astype(jnp.int32)
            y = _dispatch_combine_local(
                xl.reshape(T, D), gates, idx, wg, wu, wd, cfg, capacity,
                a2a=None, expert_offset=off,
            ).reshape(b, s, D)
            y = C.rotor_all_reduce(y, tp_axis, mode="direct")
            aux = _aux_loss(probs, idx, E)
            return y, aux

        y, aux = shard_map(
            shard_fn,
            in_specs=(in_spec, P(), P(tp_axis, None, None),
                      P(tp_axis, None, None), P(tp_axis, None, None)),
            out_specs=(in_spec, P()),
            check_vma=False,
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    return y + shared, aux


def _capacity(T: int, k: int, E: int, cf: float) -> int:
    c = int(np.ceil(T * k / E * cf))
    return max(4, ((c + 3) // 4) * 4)
