"""Pure-JAX model zoo for the 10 assigned architectures."""
from repro.models.model import (  # noqa: F401
    count_params,
    forward_decode,
    forward_prefill,
    forward_train,
    init_params,
    loss_fn,
    param_shapes,
)
from repro.models.parallel import ParallelContext, single_device_ctx  # noqa: F401
