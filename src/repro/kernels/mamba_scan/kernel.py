"""Pallas TPU selective-scan kernel (Mamba-1 core).

TPU adaptation: the GPU implementation parallelizes over (B, D) threads
with registers carrying h; on TPU we tile D into VMEM-sized blocks
(grid = (B, D/bd, S/bs)) with the (bd, N) state carried in VMEM scratch
across sequential seq-chunk grid steps, and the within-chunk recurrence
unrolled over the chunk as (bd, N)-shaped VPU ops.  dA/dBx are computed
on the fly in VMEM — the (B, S, D, N) discretized tensors never touch
HBM (the reason a fused kernel exists at all).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_ref,
            *, block_s: int):
    ks = pl.program_id(2)

    @pl.when(ks == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)      # (bs, bd)
    dt = dt_ref[0].astype(jnp.float32)    # (bs, bd)
    Bm = b_ref[0].astype(jnp.float32)     # (bs, N)
    Cm = c_ref[0].astype(jnp.float32)     # (bs, N)
    A = a_ref[...].astype(jnp.float32)    # (bd, N)
    D = d_ref[...].astype(jnp.float32)    # (1, bd)

    def step(t, carry):
        h, ys = carry
        dA = jnp.exp(dt[t][:, None] * A)                    # (bd, N)
        dBx = (dt[t] * x[t])[:, None] * Bm[t][None, :]      # (bd, N)
        h = dA * h + dBx
        y = (h * Cm[t][None, :]).sum(axis=1)                # (bd,)
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y[None], t, axis=0)
        return h, ys

    h0 = h_ref[...]
    ys0 = jnp.zeros((block_s, x.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, block_s, step, (h0, ys0))
    h_ref[...] = h
    y_ref[0] = (ys + x * D).astype(y_ref.dtype)


def mamba_scan_fwd(
    x: jnp.ndarray, dt: jnp.ndarray, Bm: jnp.ndarray, Cm: jnp.ndarray,
    A: jnp.ndarray, D: jnp.ndarray,
    block_d: int, block_s: int, interpret: bool,
) -> jnp.ndarray:
    Bsz, S, Dd = x.shape
    N = A.shape[1]
    nd = Dd // block_d
    ns = S // block_s
    grid = (Bsz, nd, ns)  # seq innermost: h carried across seq chunks

    kernel = functools.partial(_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, block_s, block_d), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, block_s, N), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, N), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((block_d, N), lambda b, d, s: (d, 0)),
            pl.BlockSpec((1, block_d), lambda b, d, s: (0, d)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_d), lambda b, d, s: (b, s, d)),
        out_shape=jax.ShapeDtypeStruct((Bsz, S, Dd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, Bm, Cm, A, D.reshape(1, Dd))
