"""Pure-jnp oracle for the selective-scan kernel."""
from __future__ import annotations

import jax.numpy as jnp


def mamba_scan_ref(
    x: jnp.ndarray,    # (B, S, D)  conv+silu'd inputs
    dt: jnp.ndarray,   # (B, S, D)  softplus'd step sizes
    Bm: jnp.ndarray,   # (B, S, N)
    Cm: jnp.ndarray,   # (B, S, N)
    A: jnp.ndarray,    # (D, N)     negative
    D: jnp.ndarray,    # (D,)
) -> jnp.ndarray:
    """Sequential reference: h_t = exp(dt_t*A) h_{t-1} + dt_t*B_t*x_t;
    y_t = C_t . h_t + D*x_t.  Returns (B, S, D) float32."""
    Bsz, S, Dd = x.shape
    N = A.shape[1]
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    B32 = Bm.astype(jnp.float32)
    C32 = Cm.astype(jnp.float32)
    h = jnp.zeros((Bsz, Dd, N), jnp.float32)
    ys = []
    for t in range(S):
        dA = jnp.exp(dt32[:, t, :, None] * A)                     # (B,D,N)
        dBx = (dt32[:, t] * x32[:, t])[..., None] * B32[:, t, None, :]
        h = dA * h + dBx
        ys.append(jnp.einsum("bdn,bn->bd", h, C32[:, t]))
    y = jnp.stack(ys, axis=1)
    return y + x32 * D
