"""jit'd public wrapper for the selective-scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.mamba_scan.kernel import mamba_scan_fwd


def _pick(s: int, target: int) -> int:
    b = min(target, s)
    while s % b:
        b -= 1
    return max(b, 1)


@functools.partial(
    jax.jit, static_argnames=("block_d", "block_s", "interpret")
)
def mamba_scan(
    x: jnp.ndarray,    # (B, S, D)
    dt: jnp.ndarray,   # (B, S, D)
    Bm: jnp.ndarray,   # (B, S, N)
    Cm: jnp.ndarray,   # (B, S, N)
    A: jnp.ndarray,    # (D, N)
    D: jnp.ndarray,    # (D,)
    block_d: int = 512,
    block_s: int = 64,
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = resolve_interpret(interpret)
    bd = _pick(x.shape[2], block_d)
    bs = _pick(x.shape[1], block_s)
    return mamba_scan_fwd(x, dt, Bm, Cm, A, D, block_d=bd, block_s=bs,
                          interpret=interpret)
