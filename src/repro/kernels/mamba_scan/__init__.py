from repro.kernels.mamba_scan.ops import mamba_scan  # noqa: F401
from repro.kernels.mamba_scan.ref import mamba_scan_ref  # noqa: F401
