"""jit'd public wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.rglru_scan.kernel import rglru_scan_fwd


def _pick(s: int, target: int) -> int:
    b = min(target, s)
    while s % b:
        b -= 1
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("block_d", "block_s", "interpret"))
def rglru_scan(
    a: jnp.ndarray,    # (B, S, D) decay gates in (0, 1)
    bx: jnp.ndarray,   # (B, S, D) gated inputs
    h0: jnp.ndarray,   # (B, D)
    block_d: int = 512,
    block_s: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = resolve_interpret(interpret)
    bd = _pick(a.shape[2], block_d)
    bs = _pick(a.shape[1], block_s)
    return rglru_scan_fwd(a, bx, h0, block_d=bd, block_s=bs,
                          interpret=interpret)
