"""Pure-jnp oracle for the RG-LRU diagonal-recurrence kernel."""
from __future__ import annotations

import jax.numpy as jnp


def rglru_scan_ref(a: jnp.ndarray, bx: jnp.ndarray, h0: jnp.ndarray):
    """h_t = a_t * h_{t-1} + bx_t.  a, bx: (B, S, D); h0: (B, D).
    Returns the full state sequence (B, S, D) float32."""
    B, S, D = a.shape
    h = h0.astype(jnp.float32)
    out = []
    a32, b32 = a.astype(jnp.float32), bx.astype(jnp.float32)
    for t in range(S):
        h = a32[:, t] * h + b32[:, t]
        out.append(h)
    return jnp.stack(out, axis=1)
