from repro.kernels.rglru_scan.ops import rglru_scan  # noqa: F401
from repro.kernels.rglru_scan.ref import rglru_scan_ref  # noqa: F401
