"""Pallas TPU kernel for the RG-LRU diagonal linear recurrence.

Grid (B, D/bd, S/bs); (1, bd) state in VMEM scratch carried across the
sequential seq-chunk steps; the within-chunk recurrence runs as bd-wide
VPU ops.  Gates (a, bx) are computed upstream (they are plain matmuls +
elementwise, which XLA fuses well); the kernel owns only the part XLA
serializes poorly — the length-S dependent chain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, out_ref, h_ref, *, block_s: int):
    ks = pl.program_id(2)

    @pl.when(ks == 0)
    def _init():
        h_ref[...] = h0_ref[0][None].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)   # (bs, bd)
    b = b_ref[0].astype(jnp.float32)   # (bs, bd)

    def step(t, carry):
        h, out = carry
        h = a[t][None, :] * h + b[t][None, :]
        out = jax.lax.dynamic_update_slice_in_dim(out, h, t, axis=0)
        return h, out

    h0 = h_ref[...]
    out0 = jnp.zeros((block_s, a.shape[1]), jnp.float32)
    h, out = jax.lax.fori_loop(0, block_s, step, (h0, out0))
    h_ref[...] = h
    out_ref[0] = out


def rglru_scan_fwd(
    a: jnp.ndarray, bx: jnp.ndarray, h0: jnp.ndarray,
    block_d: int, block_s: int, interpret: bool,
) -> jnp.ndarray:
    B, S, D = a.shape
    grid = (B, D // block_d, S // block_s)
    kernel = functools.partial(_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, block_s, block_d), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, block_d), lambda b, d, s: (b, d)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_d), lambda b, d, s: (b, s, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(a, bx, h0)
