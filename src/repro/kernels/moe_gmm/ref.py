"""Pure-jnp oracle for the grouped expert GEMM."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_gmm_ref(h: jnp.ndarray, wg: jnp.ndarray, wu: jnp.ndarray,
                wd: jnp.ndarray) -> jnp.ndarray:
    """Gated per-expert FFN over capacity-padded buffers.

    h: (E, C, D); wg/wu: (E, D, F); wd: (E, F, D).  Returns (E, C, D).
    """
    h32 = h.astype(jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", h32, wg.astype(jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", h32, wu.astype(jnp.float32))
    act = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", act, wd.astype(jnp.float32)).astype(h.dtype)
