"""Pallas TPU grouped expert-FFN GEMM (MegaBlocks-style, capacity layout).

One fused kernel computes silu(h@Wg) * (h@Wu) @ Wd for every expert's
capacity-padded token buffer.  Grid (E, C/bc, F/bf): for each (expert,
token-block) the F dimension is walked innermost, accumulating the
down-projection into VMEM scratch so the (bc, F) activation never
round-trips to HBM.  All matmul tiles are 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(h_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, nf: int):
    kf = pl.program_id(2)

    @pl.when(kf == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = h_ref[0].astype(jnp.float32)        # (bc, D)
    wg = wg_ref[0].astype(jnp.float32)      # (D, bf)
    wu = wu_ref[0].astype(jnp.float32)
    wd = wd_ref[0].astype(jnp.float32)      # (bf, D)

    g = jax.lax.dot(h, wg, preferred_element_type=jnp.float32)
    u = jax.lax.dot(h, wu, preferred_element_type=jnp.float32)
    act = jax.nn.silu(g) * u                # (bc, bf)
    acc_ref[...] += jax.lax.dot(act, wd, preferred_element_type=jnp.float32)

    @pl.when(kf == nf - 1)
    def _final():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm_fwd(
    h: jnp.ndarray,   # (E, C, D)
    wg: jnp.ndarray,  # (E, D, F)
    wu: jnp.ndarray,
    wd: jnp.ndarray,  # (E, F, D)
    block_c: int, block_f: int, interpret: bool,
) -> jnp.ndarray:
    E, C, D = h.shape
    F = wg.shape[2]
    nf = F // block_f
    grid = (E, C // block_c, nf)
    kernel = functools.partial(_kernel, nf=nf)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, D), lambda e, c, f: (e, c, 0)),
            pl.BlockSpec((1, D, block_f), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, D, block_f), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, block_f, D), lambda e, c, f: (e, f, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, D), lambda e, c, f: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), h.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, D), jnp.float32)],
        interpret=interpret,
    )(h, wg, wu, wd)
