from repro.kernels.moe_gmm.ops import moe_gmm  # noqa: F401
from repro.kernels.moe_gmm.ref import moe_gmm_ref  # noqa: F401
