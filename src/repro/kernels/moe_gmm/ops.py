"""jit'd public wrapper for the grouped expert GEMM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.moe_gmm.kernel import moe_gmm_fwd


def _pick(s: int, target: int) -> int:
    b = min(target, s)
    while s % b:
        b -= 1
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "interpret"))
def moe_gmm(
    h: jnp.ndarray,   # (E, C, D)
    wg: jnp.ndarray,  # (E, D, F)
    wu: jnp.ndarray,
    wd: jnp.ndarray,  # (E, F, D)
    block_c: int = 128,
    block_f: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = resolve_interpret(interpret)
    bc = _pick(h.shape[1], block_c)
    bf = _pick(wg.shape[2], block_f)
    return moe_gmm_fwd(h, wg, wu, wd, block_c=bc, block_f=bf,
                       interpret=interpret)
