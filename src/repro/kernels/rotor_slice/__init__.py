from repro.kernels.rotor_slice.ops import rotor_slice_step  # noqa: F401
