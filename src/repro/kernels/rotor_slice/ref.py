"""Pure-jnp oracle for the permutation-sparse rotor slice step.

One Opera slice moves bytes over a union of involutive matchings: the
``(N, u)`` int32 index tensor ``dst`` (`OperaTopology.
matching_index_tensor()` slice) holds each rack's destination per
switch slot, with the sentinel ``N`` marking dark slots (switch
reconfiguring, or a matching's self-loop).  The step is the same math
as the dense `fluid_jax._slice_step` — send own bytes on direct
circuits, forward relayed bytes into leftover room, then VLB-spread
ineligible bytes — but every per-edge quantity lives in ``(B, N, u)``
edge layout instead of ``(B, N, N)`` masks, so the arithmetic is
O(B·N·(N+u)) instead of the dense engine's O(B·N²·u) relay matmul.

Two structural tricks keep it scatter-free (XLA CPU scatters serialize):

* ``_apply_edges`` realises ``dense[b, i, dst[i, s]] += vals[b, i, s]``
  as u fused compare-selects against an iota — the sentinel never
  matches, so dark slots drop out with no clamping epsilon.
* the relay scatter ``relay[dst[j, s], :] += ...`` becomes a gather,
  because matchings are involutions: ``dst[dst[j, s], s] == j``.

`kernels/rotor_slice/kernel.py` is the Pallas form of this exact math
and `ops.py` parity-gates the two; `fluid_jax._sparse_slice_step`
drives it and `fluid.rotor_slice_step` (numpy, f64) stays the
engine-level oracle.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def apply_edges(dense: jnp.ndarray, dst: jnp.ndarray,
                vals: jnp.ndarray) -> jnp.ndarray:
    """``dense[b, i, dst[i, s]] += vals[b, i, s]`` without a scatter.

    One fused compare-select per switch slot: ``dst[:, s]`` broadcast
    against a column iota marks each slot's live edges.  Sentinel rows
    (``dst == N``) never match the iota, so invalid slots contribute
    exactly 0.0 — no index clamping, no masking epsilon.

    The selects nest into a single accumulator that is added to
    ``dense`` once at the end, not once per slot.  This REQUIRES the
    Opera slice property that slots are disjoint — each (i, j) pair is
    served by at most one switch per slice — so at most one select fires
    per element and nesting is exactly the sum (later slots pass
    non-hits through).  Bitwise-identical to the add-per-slot form
    (adding the skipped slots' 0.0 was a no-op), but u-1 fewer full
    (B, N, N) add passes — measured ~15% off the whole sparse step at
    N = 432 on XLA CPU.
    """
    n = dense.shape[-1]
    iota = jnp.arange(n, dtype=dst.dtype)
    acc = None
    for s in range(dst.shape[1]):
        hit = (dst[:, s:s + 1] == iota[None, :])[None]    # (1, N, N)
        v = vals[:, :, s:s + 1]
        acc = jnp.where(hit, v, 0.0) if acc is None else jnp.where(hit, v, acc)
    return dense + acc


def rotor_slice_ref(
    own: jnp.ndarray,     # (B, N, N) undelivered source->dst bytes
    relay: jnp.ndarray,   # (B, N, N) relayed bytes awaiting 2nd hop
    dst: jnp.ndarray,     # (N, u) int32, sentinel N = dark slot
    vlb: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One slice step in edge layout; returns (own, relay, delivered,
    moved) with (B,) delivered/VLB-spread totals in normalized units
    (every live edge carries capacity 1.0 for one slice)."""
    bsz, n = own.shape[0], own.shape[1]
    u = dst.shape[1]
    valid = dst < n
    dstc = jnp.where(valid, dst, 0)
    vf = valid.astype(own.dtype)[None]                    # (1, N, u)
    idx = jnp.broadcast_to(dstc[None], (bsz, n, u))

    # direct sends + relay forwarding, all in (B, N, u) edge layout
    own_e = jnp.take_along_axis(own, idx, axis=2) * vf
    send_own_e = jnp.minimum(own_e, vf)
    room_e = vf - send_own_e
    relay_e = jnp.take_along_axis(relay, idx, axis=2) * vf
    send_relay_e = jnp.minimum(relay_e, room_e)
    room_e = room_e - send_relay_e
    delivered = send_own_e.sum((1, 2)) + send_relay_e.sum((1, 2))

    own = apply_edges(own, dst, -send_own_e)
    relay = apply_edges(relay, dst, -send_relay_e)
    if not vlb:
        return own, relay, delivered, jnp.zeros_like(delivered)

    # VLB spread.  Eligible bytes are those with no live circuit this
    # slice; subtracting the *pre-send* edge value own_e realises the
    # dense `where(adj > 0, 0, own)` with exact zeros at live edges.
    elig = apply_edges(own, dst, -(own_e - send_own_e))
    q = elig.sum(2)
    r = room_e.sum(2)
    t = jnp.minimum(q, r)
    frac = jnp.where(q > 0, t / jnp.maximum(q, 1e-30), 0.0)[:, :, None]
    take = elig * frac
    share_e = room_e * jnp.where(
        r > 0, 1.0 / jnp.maximum(r, 1e-30), 0.0)[:, :, None]
    own = own - take
    # relay[j, :] += sum_s share_e[dst[j, s], s] * take[dst[j, s], :]
    # — the involution turns the scatter into a row gather.
    g_share = jnp.take_along_axis(share_e, idx, axis=1)
    w = vf * g_share
    add = jnp.zeros_like(relay)
    for s in range(u):
        add = add + w[:, :, s:s + 1] * jnp.take(take, dstc[:, s], axis=1)
    relay = relay + add
    return own, relay, delivered, t.sum(1)


def rotor_slice_faulted_ref(
    own: jnp.ndarray,       # (B, N, N)
    relay: jnp.ndarray,     # (B, N, N)
    dst: jnp.ndarray,       # (N, u) int32, sentinel N
    up_f: jnp.ndarray,      # (B, N, u) bool — uplink failed (real)
    up_k: jnp.ndarray,      # (B, N, u) bool — uplink failure known
    tor_f: jnp.ndarray,     # (B, N) bool — ToR failed (real)
    tor_k: jnp.ndarray,     # (B, N) bool — ToR failure known
    pair_dead: jnp.ndarray,  # (B, N, N) 0/1 — pair's serving switch dead
    vlb: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray]:
    """Faulted slice step in edge layout — mirrors
    `fluid.rotor_slice_step_faulted` (and the dense
    `fluid_jax._slice_step_faulted`); change the three together.

    Slot s of ``dst`` *is* switch s, so the per-uplink masks apply
    directly by slot — no switch-id gather.  An edge is down (really /
    known) when either endpoint's uplink into s is down or either ToR
    is down; the far endpoint's state arrives by the same involution
    gather as the relay spread.  Returns (own, relay, delivered, moved,
    blackholed) with (B,) totals.
    """
    bsz, n = own.shape[0], own.shape[1]
    u = dst.shape[1]
    valid = dst < n
    dstc = jnp.where(valid, dst, 0)
    vf = valid.astype(own.dtype)[None]
    idx = jnp.broadcast_to(dstc[None], (bsz, n, u))

    g_f = jnp.take_along_axis(up_f, idx, axis=1)     # up_f[b, dst[i,s], s]
    g_k = jnp.take_along_axis(up_k, idx, axis=1)
    tor_f_dst = jnp.take_along_axis(
        tor_f, jnp.broadcast_to(dstc[None], (bsz, n, u)).reshape(bsz, -1),
        axis=1).reshape(bsz, n, u)
    tor_k_dst = jnp.take_along_axis(
        tor_k, jnp.broadcast_to(dstc[None], (bsz, n, u)).reshape(bsz, -1),
        axis=1).reshape(bsz, n, u)
    e_real_e = (up_f | g_f | tor_f[:, :, None] | tor_f_dst).astype(own.dtype)
    e_known_e = (up_k | g_k | tor_k[:, :, None] | tor_k_dst).astype(own.dtype)
    tor_real = tor_f.astype(own.dtype)
    tor_known = tor_k.astype(own.dtype)

    cap_e = vf * (1.0 - e_known_e) * (1.0 - tor_real)[:, :, None]
    arrive_e = 1.0 - e_real_e
    own_e = jnp.take_along_axis(own, idx, axis=2) * vf
    send_own_e = jnp.minimum(own_e, cap_e)
    room_e = cap_e - send_own_e
    relay_e = jnp.take_along_axis(relay, idx, axis=2) * vf
    send_relay_e = jnp.minimum(relay_e, room_e)
    room_e = room_e - send_relay_e

    own = apply_edges(own, dst, -send_own_e * arrive_e)
    relay = apply_edges(relay, dst, -send_relay_e * arrive_e)
    delivered = ((send_own_e * arrive_e).sum((1, 2))
                 + (send_relay_e * arrive_e).sum((1, 2)))
    attempted = send_own_e.sum((1, 2)) + send_relay_e.sum((1, 2))
    blackholed = attempted - delivered
    if not vlb:
        return own, relay, delivered, jnp.zeros_like(delivered), blackholed

    # Eligibility excludes exactly the edges with usable capacity this
    # slice (cap_e > 0), not merely the live ones: a known-down edge's
    # bytes must VLB-spread.  Zero those edges by subtracting their
    # current values, then weight by destination-ToR health.
    dst_ok = 1.0 - tor_known
    own_after_e = jnp.take_along_axis(own, idx, axis=2)
    capmask_vals = jnp.where(cap_e > 0, own_after_e, 0.0)
    elig = apply_edges(own, dst, -capmask_vals) * dst_ok[:, None, :]
    relig = relay * pair_dead * dst_ok[:, None, :]
    q = elig.sum(2) + relig.sum(2)
    r = room_e.sum(2)
    t = jnp.minimum(q, r)
    frac = jnp.where(q > 0, t / jnp.maximum(q, 1e-30), 0.0)[:, :, None]
    take = elig * frac
    rtake = relig * frac
    share_e = room_e * jnp.where(
        r > 0, 1.0 / jnp.maximum(r, 1e-30), 0.0)[:, :, None]
    lost = (share_e * e_real_e).sum(2)
    own = own - take + take * lost[:, :, None]
    relay = relay - rtake + rtake * lost[:, :, None]
    sa = share_e * arrive_e
    trt = take + rtake
    g_sa = jnp.take_along_axis(sa, idx, axis=1)
    w = vf * g_sa
    add = jnp.zeros_like(relay)
    for s in range(u):
        add = add + w[:, :, s:s + 1] * jnp.take(trt, dstc[:, s], axis=1)
    relay = relay + add
    lost_bytes = (trt.sum(2) * lost).sum(1)
    moved = t.sum(1) - lost_bytes
    blackholed = blackholed + lost_bytes
    return own, relay, delivered, moved, blackholed
