"""Pallas permutation-sparse rotor slice step.

Grid (B / block_b,): the vmapped scenario batch rides the Pallas grid,
one (block_b, N, N) state tile per cell; the (N, u) destination-index
tensor (`OperaTopology.matching_index_tensor()` slice, sentinel N for
dark slots) is broadcast to every cell.  The body is the edge-layout
math of `ref.rotor_slice_ref` — gathers into (block_b, N, u), compare-
select chains instead of scatters (see ref.py for why both are exact) —
so one cell does O(N·(N + u)) work where the dense engine's relay
matmul does O(N²·u).

`ops.py` picks block_b per backend: one scenario per cell on TPU (each
tile fits VMEM up to N ≈ 1k f32), the whole batch in a single cell
under interpretation — XLA CPU executes consecutive grid steps of one
program several-fold slower than the same body as one fused block (the
measured multi-step pathology that also rules out `lax.scan` driving;
see fluid_jax._run_batch_sparse).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _apply_edges(dense, dst, vals, iota):
    """dense[b, i, dst[i, s]] += vals[b, i, s] as a nested select tree
    (slots are disjoint, see ref.apply_edges); the sentinel never
    matches the iota so dark slots add exactly 0."""
    acc = None
    for s in range(dst.shape[1]):
        hit = (dst[:, s:s + 1] == iota[None, :])[None]
        v = vals[:, :, s:s + 1]
        acc = jnp.where(hit, v, 0.0) if acc is None else jnp.where(hit, v, acc)
    return dense + acc


def _kernel(dst_ref, own_ref, relay_ref,
            own_o, relay_o, deliv_o, moved_o, *, vlb: bool):
    own = own_ref[...]          # (block_b, N, N)
    relay = relay_ref[...]
    dst = dst_ref[...]          # (N, u)
    bsz, n = own.shape[0], own.shape[1]
    u = dst.shape[1]
    iota = jnp.arange(n, dtype=dst.dtype)
    valid = dst < n
    dstc = jnp.where(valid, dst, 0)
    vf = valid.astype(own.dtype)[None]
    idx = jnp.broadcast_to(dstc[None], (bsz, n, u))

    own_e = jnp.take_along_axis(own, idx, axis=2) * vf
    send_own_e = jnp.minimum(own_e, vf)
    room_e = vf - send_own_e
    relay_e = jnp.take_along_axis(relay, idx, axis=2) * vf
    send_relay_e = jnp.minimum(relay_e, room_e)
    room_e = room_e - send_relay_e
    delivered = send_own_e.sum((1, 2)) + send_relay_e.sum((1, 2))

    own = _apply_edges(own, dst, -send_own_e, iota)
    relay = _apply_edges(relay, dst, -send_relay_e, iota)
    if vlb:
        elig = _apply_edges(own, dst, -(own_e - send_own_e), iota)
        q = elig.sum(2)
        r = room_e.sum(2)
        t = jnp.minimum(q, r)
        frac = jnp.where(q > 0, t / jnp.maximum(q, 1e-30), 0.0)[:, :, None]
        take = elig * frac
        share_e = room_e * jnp.where(
            r > 0, 1.0 / jnp.maximum(r, 1e-30), 0.0)[:, :, None]
        own = own - take
        g_share = jnp.take_along_axis(share_e, idx, axis=1)
        w = vf * g_share
        add = jnp.zeros_like(relay)
        for s in range(u):
            add = add + w[:, :, s:s + 1] * jnp.take(take, dstc[:, s], axis=1)
        relay = relay + add
        moved = t.sum(1)
    else:
        moved = jnp.zeros_like(delivered)

    own_o[...] = own
    relay_o[...] = relay
    deliv_o[...] = delivered[:, None]
    moved_o[...] = moved[:, None]


def rotor_slice_fwd(
    own: jnp.ndarray,     # (B, N, N)
    relay: jnp.ndarray,   # (B, N, N)
    dst: jnp.ndarray,     # (N, u) int32
    vlb: bool, block_b: int, interpret: bool,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    bsz, n = own.shape[0], own.shape[1]
    u = dst.shape[1]
    grid = (bsz // block_b,)
    state_spec = pl.BlockSpec((block_b, n, n), lambda b: (b, 0, 0))
    scalar_spec = pl.BlockSpec((block_b, 1), lambda b: (b, 0))
    own2, relay2, deliv, moved = pl.pallas_call(
        functools.partial(_kernel, vlb=vlb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, u), lambda b: (0, 0)),
            state_spec,
            state_spec,
        ],
        out_specs=[state_spec, state_spec, scalar_spec, scalar_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, n, n), own.dtype),
            jax.ShapeDtypeStruct((bsz, n, n), own.dtype),
            jax.ShapeDtypeStruct((bsz, 1), own.dtype),
            jax.ShapeDtypeStruct((bsz, 1), own.dtype),
        ],
        interpret=interpret,
    )(dst, own, relay)
    return own2, relay2, deliv[:, 0], moved[:, 0]
