"""jit'd public wrapper for the permutation-sparse rotor slice step."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.rotor_slice.kernel import rotor_slice_fwd
from repro.kernels.rotor_slice.ref import rotor_slice_ref


@functools.partial(
    jax.jit, static_argnames=("vlb", "block_b", "interpret", "force_pallas"))
def rotor_slice_step(
    own: jnp.ndarray,     # (B, N, N) undelivered bytes, normalized units
    relay: jnp.ndarray,   # (B, N, N) in-flight relayed bytes
    dst: jnp.ndarray,     # (N, u) int32 destination indices, sentinel N
    vlb: bool = True,
    block_b: Optional[int] = None,
    interpret: Optional[bool] = None,
    force_pallas: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One Opera slice over a scenario batch; returns (own, relay,
    delivered, moved) with (B,) delivered / VLB-spread totals.

    Off TPU (``interpret`` resolves True) the oracle math of
    `ref.rotor_slice_ref` is dispatched directly: the Pallas interpreter
    adds a fixed per-call cost that is material against the sub-ms step
    this op targets (~15% at N = 432 on one CPU core), and the kernel
    body is the same jnp expression graph either way.  Pass
    ``force_pallas=True`` to route through ``pl.pallas_call(
    interpret=True)`` anyway — the kernel-exercise mode the parity tests
    use.  On TPU the Pallas kernel runs with one scenario per grid cell
    (``block_b=1``); each (block_b, N, N) tile fits VMEM up to N ~ 1k.
    """
    interpret = resolve_interpret(interpret)
    if interpret and not force_pallas:
        return rotor_slice_ref(own, relay, dst, vlb=vlb)
    if block_b is None:
        block_b = own.shape[0] if interpret else 1
    if own.shape[0] % block_b:
        raise ValueError(
            f"batch {own.shape[0]} not divisible by block_b {block_b}")
    return rotor_slice_fwd(own, relay, dst, vlb=vlb, block_b=block_b,
                           interpret=interpret)
