"""Pure-jnp oracle for the flash attention kernel (GQA, causal, window)."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def flash_attention_ref(
    q: jnp.ndarray,   # (B, Hq, Sq, hd)
    k: jnp.ndarray,   # (B, Hkv, Sk, hd)
    v: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, hd).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * hd**-0.5
    qpos = jnp.arange(Sq) + (Sk - Sq)  # right-aligned query positions
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, hd).astype(q.dtype)
