"""jit'd public wrapper for the flash attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _pick_block(s: int, target: int) -> int:
    b = min(target, s)
    while s % b:
        b -= 1
    return max(b, 1)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "interpret")
)
def flash_attention(
    q: jnp.ndarray,   # (B, Hq, Sq, hd)
    k: jnp.ndarray,   # (B, Hkv, Sk, hd)
    v: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = resolve_interpret(interpret)
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(Sk, block_k)
    qf = q.reshape(B * Hq, Sq, hd)
    kf = k.reshape(B * Hkv, Sk, hd)
    vf = v.reshape(B * Hkv, Sk, hd)
    o = flash_attention_fwd(
        qf, kf, vf, group=g, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    return o.reshape(B, Hq, Sq, hd)
