"""Pallas TPU flash-attention kernel (forward), GQA + causal + window.

TPU adaptation (DESIGN.md §3.3): HBM->VMEM streaming of K/V blocks with
the online-softmax accumulator held in VMEM scratch; the grid is
(batch*heads, q_blocks, kv_blocks) with the kv dimension innermost so the
scratch carries across sequential kv steps; block shapes are multiples of
128 on the lane dimension so Q@K^T and P@V land on the MXU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref,           # VMEM blocks
    o_ref,                         # output block
    m_ref, l_ref, acc_ref,         # scratch
    *, scale: float, causal: bool, window: int,
    block_q: int, block_k: int, nk: int, q_offset: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                           # (bq, bk)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                 # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _final():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,    # (BH, Sq, hd) — heads folded by ops.py
    k: jnp.ndarray,    # (BHkv, Sk, hd)
    v: jnp.ndarray,
    group: int,        # Hq // Hkv (BH row -> BHkv row mapping)
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    interpret: bool,
) -> jnp.ndarray:
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    nq = Sq // block_q
    nk = Sk // block_k
    grid = (BH, nq, nk)

    kernel = functools.partial(
        _kernel,
        scale=hd**-0.5,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        nk=nk,
        q_offset=Sk - Sq,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec(
                (1, block_k, hd),
                lambda bh, iq, ik, g=group: (bh // g, ik, 0),
            ),
            pl.BlockSpec(
                (1, block_k, hd),
                lambda bh, iq, ik, g=group: (bh // g, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
