# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
from __future__ import annotations

from typing import Optional


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Shared backend auto-detection for every kernel's `ops.py` wrapper.

    `None` (the default everywhere) means "Pallas-compile on TPU,
    interpret elsewhere" — the repo's kernels are Mosaic-TPU kernels,
    and interpret mode is the supported CPU/GPU execution path.  An
    explicit bool is passed through, so tests can force interpretation
    on any backend.
    """
    if interpret is None:
        import jax

        return jax.default_backend() != "tpu"
    return bool(interpret)
