"""Opera-DP: the fully-explicit data-parallel trainer.

The whole train step runs inside one `shard_map` over the DP axes: every
shard computes local grads with pure jnp, then

  bulk class    -> gradients via hierarchical rotor schedule
                   (reduce-scatter over `data`, direct exchange over
                   `pod`, all-gather over `data`) — every byte one hop
                   per phase, Opera's tax-free direct circuits;
  latency class -> scalar telemetry (loss/aux) via immediate multi-hop
                   expander gossip (`expander_psum_latency`);
  compression   -> optional int8 + error-feedback on the wire
                   (`compressed_rotor_all_reduce`), a beyond-paper
                   distributed-optimization trick.

Best suited to models whose params fit replicated (smollm-class); large
archs use the GSPMD trainer (train/trainer.py) where the rotor schedule
rides the pod axis and the MoE dispatch.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.core import collectives as C
from repro.models.model import loss_fn
from repro.models.parallel import ParallelContext, single_device_ctx
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


def make_opera_dp_train_step(
    cfg: ModelConfig,
    pctx: ParallelContext,
    opt: AdamWConfig,
    compress: bool = False,
):
    mesh = pctx.mesh
    data_axis = pctx.dp_axes[-1]
    pod_axis = pctx.pod_axis
    n_shards = pctx.dp_size
    local_ctx = single_device_ctx()

    def per_shard(params, opt_state, err, batch):
        (_, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, local_ctx), has_aux=True
        )(params)

        if compress:
            def sync(g, e):
                tot, ne = C.compressed_rotor_all_reduce(g, data_axis, e)
                if pod_axis is not None:
                    tot = C.rotor_all_reduce(tot, pod_axis, mode="direct")
                return tot / n_shards, ne

            pairs = jax.tree.map(sync, grads, err)
            grads = jax.tree.map(lambda t: t[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
            err = jax.tree.map(lambda t: t[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        else:
            grads = jax.tree.map(
                lambda g: C.hierarchical_rotor_all_reduce(
                    g, data_axis, pod_axis
                ) / n_shards,
                grads,
            )

        # latency class: control-plane scalars cross the fabric immediately
        agg = {}
        for k, v in metrics.items():
            s = C.expander_psum_latency(v[None], data_axis)[0]
            if pod_axis is not None:
                s = C.expander_psum_latency(s[None], pod_axis)[0]
            agg[k] = s / n_shards

        new_params, new_opt, om = adamw_update(opt, params, grads, opt_state)
        agg.update(om)
        return new_params, new_opt, err, agg

    batch_spec = P(tuple(pctx.dp_axes))
    rep = P()
    mapped = shard_map(
        per_shard,
        mesh=mesh,
        in_specs=(rep, rep, rep, batch_spec),
        out_specs=(rep, rep, rep, rep),
        check_vma=False,
    )

    def train_step(state: Dict[str, Any], batch):
        err = state.get("err")
        if err is None:
            err = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                               state["params"])
        new_params, new_opt, new_err, metrics = mapped(
            state["params"], state["opt"], err, batch
        )
        out = {"params": new_params, "opt": new_opt}
        if compress:
            out["err"] = new_err
        return out, metrics

    return train_step


def init_opera_dp_state(params, compress: bool = False):
    st = {"params": params, "opt": init_opt_state(params)}
    if compress:
        st["err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    return st
