"""Mesh-agnostic (elastic) checkpointing with async save.

Checkpoints store logical (unsharded) arrays + a JSON manifest (step,
tree structure, shapes/dtypes), so a run saved on one mesh restores onto
any other — the elastic-scaling primitive.  Saves run on a background
thread (the train loop only pays for the host gather); `wait()` joins.

Layout:  <dir>/step_<N>/manifest.json + arrays.npz
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional

import numpy as np

import jax


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ---------------- save -------------------------------------------------
    def save(self, step: int, state, extra: Optional[Dict] = None,
             blocking: bool = False):
        self.wait()
        flat = _flatten(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        treedef = jax.tree_util.tree_structure(state)

        def _write():
            d = self.dir / f"step_{step:08d}"
            tmp = self.dir / f".tmp_step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz", **host)
            manifest = {
                "step": int(step),
                "treedef": str(treedef),
                "keys": sorted(host.keys()),
                "extra": extra or {},
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if d.exists():
                shutil.rmtree(d)
            tmp.rename(d)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------- restore ----------------------------------------------
    def steps(self) -> List[int]:
        return sorted(
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if (p / "manifest.json").exists()
        )

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of `like` (values or SDS).  With
        `shardings` (same tree), arrays are placed sharded — onto ANY
        mesh, not necessarily the one that saved them (elastic)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        data = np.load(d / "arrays.npz")
        flat_like = _flatten(like)
        flat_shard = _flatten(shardings) if shardings is not None else None
        out = {}
        for k, leaf in flat_like.items():
            arr = data[k]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"checkpoint leaf {k}: shape {arr.shape} != {leaf.shape}"
                )
            if flat_shard is not None:
                out[k] = jax.device_put(arr, flat_shard[k])
            else:
                out[k] = jax.numpy.asarray(arr, dtype=leaf.dtype)
        # rebuild the tree in `like`'s structure
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        keys = list(_flatten(like).keys())
        ordered = [out[k] for k in keys]
        return jax.tree_util.tree_unflatten(treedef, ordered), step
