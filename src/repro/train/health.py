"""Fault tolerance & straggler mitigation (the control plane).

Maps Opera's failure story (§3.6.2) onto the training fleet:

* hello protocol  -> per-worker heartbeats each step; a worker silent for
  `timeout_steps` is declared failed (like a link that misses its hello
  window being marked bad).
* route around    -> the rotor collective schedules are design-time
  functions of the participant set: on failure the controller shrinks the
  mesh (drop the slowest/failed host group), restores the latest elastic
  checkpoint onto the new mesh, and resumes — connectivity is re-derived,
  not repaired in place.
* guard bands     -> straggler policy: a worker whose step time exceeds
  `straggler_factor` x the fleet median for `patience` consecutive steps
  is treated as failed-slow and scheduled for replacement at the next
  checkpoint boundary (synchronous SPMD cannot proceed without it, so the
  mitigation is replace-and-restart, the standard production approach).

In this single-process environment the fleet is simulated; the policy
logic (detection, decision, restart plumbing) is the real, tested code —
see tests/test_fault_tolerance.py and examples/fault_tolerance_drill.py.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional, Set


@dataclasses.dataclass
class HealthConfig:
    timeout_steps: int = 3          # missed heartbeats before declared dead
    straggler_factor: float = 2.0   # x median step time
    patience: int = 5               # consecutive slow steps
    min_workers: int = 1


class FleetMonitor:
    """Tracks per-worker heartbeats + step durations; decides restarts."""

    def __init__(self, workers: List[str], cfg: HealthConfig = HealthConfig()):
        self.cfg = cfg
        self.workers: Set[str] = set(workers)
        self.last_seen: Dict[str, int] = {w: 0 for w in workers}
        self.durations: Dict[str, deque] = {
            w: deque(maxlen=32) for w in workers
        }
        self.slow_streak: Dict[str, int] = defaultdict(int)
        self.failed: Set[str] = set()

    def heartbeat(self, worker: str, step: int, duration_s: float):
        if worker in self.failed:
            return
        self.last_seen[worker] = step
        self.durations[worker].append(duration_s)

    def median_duration(self) -> float:
        vals = sorted(
            d[-1] for w, d in self.durations.items()
            if d and w not in self.failed
        )
        return vals[len(vals) // 2] if vals else 0.0

    def check(self, step: int) -> Dict[str, List[str]]:
        """Returns {'dead': [...], 'stragglers': [...]} newly detected."""
        dead, slow = [], []
        med = self.median_duration()
        for w in sorted(self.workers - self.failed):
            if step - self.last_seen[w] >= self.cfg.timeout_steps:
                dead.append(w)
                continue
            d = self.durations[w]
            if med > 0 and d and d[-1] > self.cfg.straggler_factor * med:
                self.slow_streak[w] += 1
                if self.slow_streak[w] >= self.cfg.patience:
                    slow.append(w)
            else:
                self.slow_streak[w] = 0
        for w in dead + slow:
            self.failed.add(w)
        return {"dead": dead, "stragglers": slow}

    def healthy(self) -> List[str]:
        return sorted(self.workers - self.failed)


@dataclasses.dataclass
class RestartPlan:
    """What the controller does on failure: shrink + restore + resume."""
    surviving_workers: List[str]
    restore_step: int
    new_mesh_shape: tuple

    @staticmethod
    def from_failure(
        monitor: FleetMonitor,
        latest_ckpt_step: int,
        devices_per_worker: int,
        model_axis: int,
    ) -> "RestartPlan":
        n = len(monitor.healthy())
        # keep the model axis, shrink data-parallel width to what survives
        data = max((n * devices_per_worker) // model_axis, 1)
        return RestartPlan(
            surviving_workers=monitor.healthy(),
            restore_step=latest_ckpt_step,
            new_mesh_shape=(data, model_axis),
        )
