"""Train-step construction: loss -> grads -> (Opera-scheduled) sync -> AdamW.

Two gradient-sync regimes (DESIGN.md §3.1):

* ``xla``    — params are FSDP-sharded over data (and replicated over pod);
               GSPMD's automatically-inserted reduce-scatter/all-reduce is
               the baseline collective schedule.
* ``rotor``  — the inter-pod reduction is performed *explicitly* by the
               rotor schedule: the whole grad/update pipeline runs inside a
               partial `shard_map` that binds only the `pod` axis (data and
               model stay auto/GSPMD inside), and the pod all-reduce is
               `rotor_all_reduce(..., mode="direct")` — one direct exchange
               per matching, Opera's bulk class.  Scalar metrics ride the
               latency class (`expander_psum_latency`).

`make_train_step(cfg, pctx, opt)` returns a pure (state, batch) -> (state,
metrics) suitable for jit with NamedShardings (launch/dryrun.py and
launch/train.py) or for single-device use in tests.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import HAS_PARTIAL_MANUAL, shard_map
from repro.configs.base import ModelConfig
from repro.core import collectives as C
from repro.models.model import loss_fn
from repro.models.parallel import ParallelContext
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


def make_train_step(cfg: ModelConfig, pctx: ParallelContext, opt: AdamWConfig):
    # The explicit rotor pod region is a *partial* shard_map (only `pod`
    # manual; data/model stay GSPMD-auto inside).  On jax 0.4.x that
    # binding aborts in XLA (compat.HAS_PARTIAL_MANUAL), so rotor grad
    # sync degrades to GSPMD-inserted inter-pod collectives there — the
    # update math is identical, only the collective schedule differs.
    use_rotor_pod = (
        cfg.grad_sync == "rotor"
        and pctx.pod_axis is not None
        and pctx.mesh is not None
        and HAS_PARTIAL_MANUAL
    )

    def grads_and_metrics(params, batch, inner_pctx):
        (total, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, inner_pctx), has_aux=True
        )(params)
        return grads, metrics

    if not use_rotor_pod:

        def train_step(state, batch):
            grads, metrics = grads_and_metrics(state["params"], batch, pctx)
            new_params, new_opt, om = adamw_update(
                opt, state["params"], grads, state["opt"]
            )
            metrics.update(om)
            return {"params": new_params, "opt": new_opt}, metrics

        return train_step

    # ---- explicit rotor inter-pod DDP -------------------------------------
    pod = pctx.pod_axis
    n_pod = int(pctx.mesh.shape[pod])
    # inside the pod-manual region the model sees only the intra-pod axes
    inner_pctx = ParallelContext(
        mesh=pctx.mesh,
        dp_axes=tuple(a for a in pctx.dp_axes if a != pod),
        tp_axis=pctx.tp_axis,
        pod_axis=None,
        moe_dispatch=pctx.moe_dispatch,
        grad_sync="xla",
        act_sharding=pctx.act_sharding,
    )

    def train_step(state, batch):
        def per_pod(params, opt_state, b):
            grads, metrics = grads_and_metrics(params, b, inner_pctx)
            # bulk class: gradients, one direct exchange per pod matching
            grads = jax.tree.map(
                lambda g: C.rotor_all_reduce(g, pod, mode="direct") / n_pod,
                grads,
            )
            # latency class: scalar telemetry crosses pods immediately
            metrics = {
                k: C.expander_psum_latency(v[None], pod)[0] / n_pod
                for k, v in metrics.items()
            }
            new_params, new_opt, om = adamw_update(opt, params, grads, opt_state)
            metrics.update(om)
            return new_params, new_opt, metrics

        # bind ONLY the pod axis; data/model stay GSPMD-auto inside
        rep = P()  # params replicated across pods (sharded inside by auto axes)
        fn = shard_map(
            per_pod,
            mesh=pctx.mesh,
            in_specs=(rep, rep, P(pod)),
            out_specs=(rep, rep, rep),
            axis_names={pod},
            check_vma=False,
        )
        batch_specced = jax.tree.map(lambda x: x, batch)
        new_params, new_opt, metrics = fn(
            state["params"], state["opt"], batch_specced
        )
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(cfg: ModelConfig, params) -> Dict[str, Any]:
    return {"params": params, "opt": init_opt_state(params)}
