"""Benchmark harness: one module per paper table/figure + framework benches.

    PYTHONPATH=src python -m benchmarks.run [--only fig08,fig12] [--skip ...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import save

MODULES = [
    ("sec41_cycle_time", "benchmarks.sec41_cycle_time"),
    ("fig04_path_lengths", "benchmarks.fig04_path_lengths"),
    ("fig08_shuffle", "benchmarks.fig08_shuffle"),
    ("fig07_datamining", "benchmarks.fig07_datamining"),
    ("fig09_websearch", "benchmarks.fig09_websearch"),
    ("fig10_mixed", "benchmarks.fig10_mixed"),
    ("fig11_faults", "benchmarks.fig11_faults"),
    ("fig12_cost", "benchmarks.fig12_cost"),
    ("netsim_sweep", "benchmarks.netsim_sweep"),
    ("perf_track", "benchmarks.perf_track"),
    ("table1_appD", "benchmarks.table1_appD"),
    ("bench_rotor_collectives", "benchmarks.bench_rotor_collectives"),
    ("bench_roofline", "benchmarks.bench_roofline"),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--skip", default="")
    args = ap.parse_args(argv)
    only = set(filter(None, args.only.split(",")))
    skip = set(filter(None, args.skip.split(",")))

    results, failed = {}, []
    t0 = time.time()
    for name, modpath in MODULES:
        if only and name not in only:
            continue
        if name in skip:
            continue
        try:
            mod = __import__(modpath, fromlist=["run"])
            out = mod.run()
            save(name, out)
            checks = out.get("checks", {})
            results[name] = dict(
                ok=all(checks.values()) if checks else True, checks=checks
            )
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failed.append((name, repr(e)))
            results[name] = dict(ok=False, error=repr(e))

    print("\n" + "=" * 78)
    print("== BENCHMARK SUMMARY")
    print("=" * 78)
    for name, r in results.items():
        status = "OK  " if r.get("ok") else "WARN"
        nchk = len(r.get("checks", {}))
        npass = sum(bool(v) for v in r.get("checks", {}).values())
        print(f"  [{status}] {name:26s} {npass}/{nchk} checks")
    print(f"  total: {time.time()-t0:.1f}s")
    save("summary", results)
    if failed:
        print(f"\n{len(failed)} benchmark(s) errored: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
