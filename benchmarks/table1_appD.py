"""Table 1 (routing state) + Appendix D (spectral gap / path optimality)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, check, save
from repro.core.expander import (
    mean_max_path,
    ramanujan_bound,
    random_regular_expander,
    spectral_gap,
)
from repro.core.routing import ruleset_size
from repro.core.topology import build_opera_topology

# Table 1 published values
PUBLISHED = {108: 12_096, 252: 65_268, 520: 276_120, 768: 600_576,
             1008: 1_032_192, 1200: 1_461_600}


def run() -> dict:
    banner("Table 1 — routing state scaling")
    table = []
    for n, pub in PUBLISHED.items():
        mine = ruleset_size(n)
        table.append(dict(racks=n, model=mine, published=pub,
                          ratio=mine / pub))
        print(f"  {n:5d} racks: model {mine:10,}  published {pub:10,} "
              f"(ratio {mine/pub:.2f})")
    ok1 = check("O(N^2) scaling matches published counts within 15%",
                all(0.85 <= r["ratio"] <= 1.15 for r in table))

    banner("Appendix D — per-slice spectral gaps vs static expanders")
    topo = build_opera_topology(108, 6, seed=0)
    gaps, means, maxes = [], [], []
    for t in range(0, topo.num_slices, 4):
        adj = topo.adjacency(t)
        gaps.append(spectral_gap(adj))
        m, mx, _ = mean_max_path(adj)
        means.append(m)
        maxes.append(mx)
    stat = random_regular_expander(108, 5, seed=3)
    sgap = spectral_gap(stat)
    sm, smx, _ = mean_max_path(stat)
    rb = ramanujan_bound(5)
    print(f"  opera slices: gap {np.mean(gaps):.3f} (min {min(gaps):.3f}) "
          f"mean path {np.mean(means):.2f} max {max(maxes)}")
    print(f"  static d=5  : gap {sgap:.3f}  mean path {sm:.2f} max {smx}")
    print(f"  ramanujan bound (d=5): {rb:.3f}")
    ok2 = check("every slice within ~35% of the static expander's gap",
                min(gaps) > 0.6 * sgap, f"min {min(gaps):.3f} vs {sgap:.3f}")
    ok3 = check("Opera path length ~ best static (App. D)",
                np.mean(means) - sm < 0.5)
    return dict(
        table1=table,
        appD=dict(opera_gap_mean=float(np.mean(gaps)),
                  opera_gap_min=float(min(gaps)), static_gap=sgap,
                  ramanujan=rb, opera_mean_path=float(np.mean(means)),
                  static_mean_path=sm),
        checks=dict(table1=ok1, gaps=ok2, paths=ok3),
    )


if __name__ == "__main__":
    save("table1_appD", run())
