"""§4.1 + §3.5 + App. B: cycle-time arithmetic and guard-band sensitivity."""
from __future__ import annotations

from benchmarks.common import banner, check, save
from repro.configs.opera_paper import OPERA_648
from repro.core.schedule import cycle_timing, scaled_cycle_table


def run() -> dict:
    banner("§4.1 — cycle-time model (648-host design point)")
    t = cycle_timing(OPERA_648)
    print(f"  epsilon          {t.epsilon_us:8.1f} us   (paper:  90 us)")
    print(f"  slice            {t.slice_us:8.1f} us   (paper: ~100 us)")
    print(f"  per-switch period{t.per_switch_period_us:8.1f} us   (paper: ~6 eps)")
    print(f"  duty cycle       {100*t.duty_cycle:8.2f} %    (paper:  98 %)")
    print(f"  cycle            {t.cycle_ms:8.2f} ms   (paper: 10.7 ms)")
    print(f"  bulk cutoff      {t.bulk_cutoff_mb:8.1f} MB   (paper:  15 MB)")
    print(f"  guard-band cost  {100*t.ll_capacity_loss_per_guard_us:.2f} %/us "
          f"latency, {100*t.bulk_capacity_loss_per_guard_us:.2f} %/us bulk "
          f"(paper: 1 %/us, 0.2 %/us)")

    rows = scaled_cycle_table()
    print("\n  App. B — grouped reconfiguration, cycle scaling:")
    for r in rows:
        print(f"    k={r['k']:2d} hosts={r['hosts']:6d} groups={r['groups']} "
              f"cycle {r['cycle_ms']:8.2f} ms (rel {r['relative_cycle']:.1f}x) "
              f"cutoff {r['bulk_cutoff_mb']:.0f} MB")
    ok1 = check("eps within 15% of paper's 90 us", 85 <= t.epsilon_us <= 110)
    ok2 = check("duty cycle ~98%", 0.97 <= t.duty_cycle <= 0.99)
    ok3 = check("cycle ~10.7 ms (+-20%)", 9.0 <= t.cycle_ms <= 13.0)
    ok4 = check("bulk cutoff ~15 MB", 11 <= t.bulk_cutoff_mb <= 18)
    k64 = [r for r in rows if r["k"] == 64][0]
    ok5 = check("k=64 cutoff ~90 MB (App. B)", 50 <= k64["bulk_cutoff_mb"] <= 140,
                f"{k64['bulk_cutoff_mb']:.0f} MB")
    return dict(
        timing=t.__dict__, scaling=rows,
        checks=dict(eps=ok1, duty=ok2, cycle=ok3, cutoff=ok4, k64=ok5),
    )


if __name__ == "__main__":
    save("sec41_cycle_time", run())
