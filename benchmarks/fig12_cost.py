"""Fig. 12 / App. A+C: cost-normalized throughput vs alpha (k=24 and k=12).

The alpha curves and their checks come from the calibrated analytic
model (netsim/capacity.py).  Alongside them, the paper's k=12 design
point is *measured* with the batched JAX fluid engine: all four
workloads (shuffle / permutation / skew / hotrack) as one vmapped batch
on the real 108-rack topology, RotorLB VLB on, throughput normalized to
the active senders' NIC bandwidth — the fluid analogue of the model's
per-workload Opera column (ideal transport, so slightly above it).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, check, save
from repro.netsim.capacity import crossover_alpha, fig12_model
from repro.netsim.sweep import DesignPoint, scenario_demand
from repro.netsim.fluid_jax import simulate_rotor_bulk_batch

FLUID_WORKLOADS = ("shuffle", "permutation", "skew", "hotrack")


def measured_opera_k12(load: float = 3.0, max_cycles: int = 8) -> dict:
    """Fluid-measured saturation throughput per workload, one batch."""
    dp = DesignPoint(k=12, num_racks=108)
    cfg = dp.to_config()
    demands = np.stack(
        [scenario_demand(w, cfg, load, seed=0) for w in FLUID_WORKLOADS]
    )
    res = simulate_rotor_bulk_batch(cfg, demands, vlb=True,
                                    max_cycles=max_cycles)
    out = {}
    for i, w in enumerate(FLUID_WORKLOADS):
        active_racks = int((demands[i].sum(1) > 0).sum())
        active_bw = active_racks * cfg.hosts_per_rack * cfg.link_rate_gbps
        out[w] = float(res.throughput_gbps[i]) / active_bw
    return out


def run() -> dict:
    banner("Fig. 12 — throughput vs Opera-port cost ratio alpha")
    out = {}
    for k in (24, 12):
        out[f"k{k}"] = {}
        for wl in ("hotrack", "skew", "permutation", "shuffle"):
            rows = [fig12_model(a, wl, k) for a in (1.0, 1.3, 1.8, 2.0)]
            out[f"k{k}"][wl] = rows
            r13, r20 = rows[1], rows[3]
            print(f"  k={k} {wl:11s} alpha=1.3: opera {r13['opera']:.2f} "
                  f"exp {r13['expander']:.2f} clos {r13['clos']:.2f} | "
                  f"alpha=2.0: opera {r20['opera']:.2f} "
                  f"exp {r20['expander']:.2f}")
    fluid = measured_opera_k12()
    out["fluid_opera_k12"] = fluid
    model12 = {wl: fig12_model(1.3, wl, 12)["opera"]
               for wl in FLUID_WORKLOADS}
    print("  fluid k=12 opera (active-sender frac): "
          + "  ".join(f"{w}={v:.2f}" for w, v in fluid.items()))
    print("  model k=12 opera                     : "
          + "  ".join(f"{w}={v:.2f}" for w, v in model12.items()))

    r = out["k24"]
    ok1 = check("shuffle: Opera ~2x best static even at alpha=2 (paper)",
                r["shuffle"][3]["opera"] >=
                1.5 * max(r["shuffle"][3]["expander"], r["shuffle"][3]["clos"]))
    ok2 = check("permutation: Opera wins at alpha<=1.3 (paper: alpha<1.8)",
                r["permutation"][1]["opera"] >=
                max(r["permutation"][1]["expander"], r["permutation"][1]["clos"]))
    ok3 = check("hotrack: Opera comparable to expander (paper)",
                r["hotrack"][1]["opera"] >= 0.55 * r["hotrack"][1]["expander"])
    xo = crossover_alpha("permutation", 24)
    ok4 = check("crossover alpha in [1.3, 2.6] (paper ~1.8)", 1.3 <= xo <= 2.6,
                f"alpha*={xo:.2f}")
    k_equal = all(
        abs(out["k24"][wl][1]["opera"] - out["k12"][wl][1]["opera"]) < 0.15
        for wl in ("shuffle", "permutation")
    )
    ok5 = check("k=12 vs k=24 nearly identical (App. C)", k_equal)
    # Fluid physics the per-port model normalizes away: VLB's second hop
    # rides the *relay* racks' uplinks, so when most racks are idle
    # (hotrack, skew) the active senders recover toward full fabric rate,
    # while the all-active permutation pays the full 100% tax (~half of
    # shuffle's direct-circuit rate).
    ok6 = check(
        "fluid k=12: permutation VLB-bound at ~half shuffle; idle-rack "
        "workloads recover via relay uplinks",
        fluid["shuffle"] >= 0.55
        and 0.25 <= fluid["permutation"] <= 0.75 * fluid["shuffle"]
        and all(fluid[w] >= fluid["permutation"] for w in ("skew", "hotrack")),
        f"fluid={ {w: round(v, 2) for w, v in fluid.items()} }",
    )
    out["crossover_alpha"] = xo
    out["checks"] = dict(shuffle2x=ok1, perm=ok2, hotrack=ok3, xover=ok4,
                         scale_invariant=ok5, fluid=ok6)
    return out


if __name__ == "__main__":
    save("fig12_cost", run())
