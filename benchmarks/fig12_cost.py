"""Fig. 12 / App. A+C: cost-normalized throughput vs alpha (k=24 and k=12)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, check, save
from repro.netsim.capacity import crossover_alpha, fig12_model


def run() -> dict:
    banner("Fig. 12 — throughput vs Opera-port cost ratio alpha")
    out = {}
    for k in (24, 12):
        out[f"k{k}"] = {}
        for wl in ("hotrack", "skew", "permutation", "shuffle"):
            rows = [fig12_model(a, wl, k) for a in (1.0, 1.3, 1.8, 2.0)]
            out[f"k{k}"][wl] = rows
            r13, r20 = rows[1], rows[3]
            print(f"  k={k} {wl:11s} alpha=1.3: opera {r13['opera']:.2f} "
                  f"exp {r13['expander']:.2f} clos {r13['clos']:.2f} | "
                  f"alpha=2.0: opera {r20['opera']:.2f} "
                  f"exp {r20['expander']:.2f}")
    r = out["k24"]
    ok1 = check("shuffle: Opera ~2x best static even at alpha=2 (paper)",
                r["shuffle"][3]["opera"] >=
                1.5 * max(r["shuffle"][3]["expander"], r["shuffle"][3]["clos"]))
    ok2 = check("permutation: Opera wins at alpha<=1.3 (paper: alpha<1.8)",
                r["permutation"][1]["opera"] >=
                max(r["permutation"][1]["expander"], r["permutation"][1]["clos"]))
    ok3 = check("hotrack: Opera comparable to expander (paper)",
                r["hotrack"][1]["opera"] >= 0.55 * r["hotrack"][1]["expander"])
    xo = crossover_alpha("permutation", 24)
    ok4 = check("crossover alpha in [1.3, 2.6] (paper ~1.8)", 1.3 <= xo <= 2.6,
                f"alpha*={xo:.2f}")
    k_equal = all(
        abs(out["k24"][wl][1]["opera"] - out["k12"][wl][1]["opera"]) < 0.15
        for wl in ("shuffle", "permutation")
    )
    ok5 = check("k=12 vs k=24 nearly identical (App. C)", k_equal)
    out["crossover_alpha"] = xo
    out["checks"] = dict(shuffle2x=ok1, perm=ok2, hotrack=ok3, xover=ok4,
                         scale_invariant=ok5)
    return out


if __name__ == "__main__":
    save("fig12_cost", run())
