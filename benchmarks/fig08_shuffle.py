"""Fig. 8: 100 KB all-to-all shuffle — Opera ~4x the static networks.

The Opera run goes through the batched JAX engine (netsim/fluid_jax.py,
a batch of one here); the static comparisons stay on the closed-form /
numpy fluid paths.
"""
from __future__ import annotations

from benchmarks.common import banner, check, save
from repro.configs.opera_paper import OPERA_648
from repro.core.expander import random_regular_expander
from repro.netsim.fluid import simulate_clos_bulk, simulate_expander_bulk
from repro.netsim.fluid_jax import simulate_rotor_bulk_jax
from repro.netsim.workloads import demand_all_to_all


def run() -> dict:
    banner("Fig. 8 — 100 KB shuffle (all-to-all), 648 hosts")
    d = demand_all_to_all(108, 6, 100e3)
    opera = simulate_rotor_bulk_jax(OPERA_648, d, vlb=False, max_cycles=40)
    clos = simulate_clos_bulk(648, d, 10.0, 3.0)
    adj = random_regular_expander(130, 7, seed=1)
    exp = simulate_expander_bulk(
        adj, demand_all_to_all(130, 5, 100e3), 10.0, dt_us=2000.0
    )
    print(f"  opera    99p FCT {opera.fct_99_ms:7.1f} ms  tax {opera.bandwidth_tax:5.2f}  tput {opera.throughput_gbps:7.0f} Gb/s   (paper:  60 ms)")
    print(f"  clos 3:1 99p FCT {clos.fct_99_ms:7.1f} ms  tax {clos.bandwidth_tax:5.2f}  tput {clos.throughput_gbps:7.0f} Gb/s   (paper: 227 ms)")
    print(f"  exp u=7  99p FCT {exp.fct_99_ms:7.1f} ms  tax {exp.bandwidth_tax:5.2f}  tput {exp.throughput_gbps:7.0f} Gb/s   (paper: 223 ms)")

    ratio = min(clos.fct_99_ms, exp.fct_99_ms) / opera.fct_99_ms
    ok1 = check("Opera 99p FCT 50-85 ms (paper 60)", 50 <= opera.fct_99_ms <= 85)
    ok2 = check("Opera pays zero bandwidth tax on shuffle",
                opera.bandwidth_tax < 0.01)
    ok3 = check("Opera >= ~2-4x faster than best static (paper ~3.7x)",
                ratio >= 1.8, f"ratio={ratio:.2f}")
    ok4 = check("expander pays a multi-hop tax >= 100%",
                exp.bandwidth_tax >= 1.0, f"tax={exp.bandwidth_tax:.2f}")
    return dict(
        opera_fct99_ms=opera.fct_99_ms, clos_fct99_ms=clos.fct_99_ms,
        expander_fct99_ms=exp.fct_99_ms, opera_tax=opera.bandwidth_tax,
        expander_tax=exp.bandwidth_tax, speedup_vs_best_static=ratio,
        paper=dict(opera=60, clos=227, expander=223),
        checks=dict(fct=ok1, taxfree=ok2, speedup=ok3, exp_tax=ok4),
    )


if __name__ == "__main__":
    save("fig08_shuffle", run())
