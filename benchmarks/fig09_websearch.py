"""Fig. 9: Websearch (all-indirect worst case) — Opera admits ~10 %.

The (network x load x seed) grid runs through the batched JAX flow
engine as one device program (`sweep.run_flow_sweep`, auto/dense/tiled
dispatch); the capacity model supplies the analytic cross-check.
"""
from __future__ import annotations

from benchmarks.common import banner, check, save
from repro.netsim.capacity import summary_648
from repro.netsim.sweep import FlowSweepSpec, run_flow_sweep, summarize

NETS = ("opera", "expander", "clos")
SIM_KW = dict(num_hosts=216, horizon_s=0.6, tail_s=0.3)


def run(loads=(0.01, 0.05, 0.10, 0.20, 0.25), seeds=(2, 3),
        engine: str = "auto") -> dict:
    banner("Fig. 9 — Websearch workload (Opera pays tax on everything)")
    rows = run_flow_sweep(
        FlowSweepSpec(networks=NETS, workloads=("websearch",),
                      loads=tuple(loads), seeds=tuple(seeds), engine=engine),
        **SIM_KW)
    mean = summarize(
        rows,
        by=("network", "load"),
        stats=("fct_p99_ms_small", "admitted", "finished_frac",
               "backlog_frac"),
    )
    out = {}
    for net in NETS:
        out[net] = [r for r in mean if r["network"] == net]
        for r in out[net]:
            print(f"  {net:9s} load {r['load']:4.2f}: small 99p "
                  f"{r['fct_p99_ms_small']:9.3f} ms  "
                  f"admitted={r['admitted']:.1f}")

    s = summary_648()
    print(f"  capacity model: opera {s['opera_latency_load']:.3f}, "
          f"expander {s['expander_load']:.3f}, clos {s['clos_load']:.3f}")
    print(f"  capacity ratio opera/expander = {s['capacity_ratio']:.2f} "
          f"(paper: 0.60), extra path tax = {100*s['extra_tax']:.0f}% "
          f"(paper: 41%)")
    ok1 = check("Opera admits ~10% (paper)",
                out["opera"][2]["admitted"] > 0.5
                and out["opera"][3]["admitted"] < 0.5)
    ok2 = check("statics admit ~25% (paper: slightly above 25%)",
                out["expander"][3]["admitted"] > 0.5)
    ok3 = check("equivalent FCTs at low load across networks",
                abs(out["opera"][0]["fct_p99_ms_small"] -
                    out["expander"][0]["fct_p99_ms_small"]) < 5.0)
    out["rows"] = rows
    out["capacity_model"] = s
    out["checks"] = dict(opera10=ok1, statics25=ok2, low_load_equal=ok3)
    return out


if __name__ == "__main__":
    save("fig09_websearch", run())
