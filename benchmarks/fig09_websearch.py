"""Fig. 9: Websearch (all-indirect worst case) — Opera admits ~10 %."""
from __future__ import annotations

from benchmarks.common import banner, check, save
from repro.netsim.capacity import summary_648
from repro.netsim.flows import simulate


def run(loads=(0.01, 0.05, 0.10, 0.20, 0.25)) -> dict:
    banner("Fig. 9 — Websearch workload (Opera pays tax on everything)")
    out = {}
    for net in ("opera", "expander", "clos"):
        rows = []
        for load in loads:
            r = simulate(net, "websearch", load, horizon_s=0.8, seed=2)
            rows.append(dict(load=load, small_p99_ms=r.fct_p99_ms_small,
                             admitted=r.admitted, finished=r.finished_frac))
            print(f"  {net:9s} load {load:4.2f}: small 99p "
                  f"{r.fct_p99_ms_small:9.3f} ms  admitted={r.admitted}")
        out[net] = rows

    s = summary_648()
    print(f"  capacity model: opera {s['opera_latency_load']:.3f}, "
          f"expander {s['expander_load']:.3f}, clos {s['clos_load']:.3f}")
    print(f"  capacity ratio opera/expander = {s['capacity_ratio']:.2f} "
          f"(paper: 0.60), extra path tax = {100*s['extra_tax']:.0f}% "
          f"(paper: 41%)")
    ok1 = check("Opera admits ~10% (paper)",
                out["opera"][2]["admitted"] and not out["opera"][3]["admitted"])
    ok2 = check("statics admit ~25% (paper: slightly above 25%)",
                out["expander"][3]["admitted"])
    ok3 = check("equivalent FCTs at low load across networks",
                abs(out["opera"][0]["small_p99_ms"] -
                    out["expander"][0]["small_p99_ms"]) < 5.0)
    out["capacity_model"] = s
    out["checks"] = dict(opera10=ok1, statics25=ok2, low_load_equal=ok3)
    return out


if __name__ == "__main__":
    save("fig09_websearch", run())
