"""Fig. 11 + App. E: fault tolerance of the 648-host Opera network.

Dynamic version: the headline columns are *measured* from the batched
fault-injected engines — throughput retention from the fluid engine
under sustained (paced) load, and FCT inflation from the flow-level
engine — with the original connectivity/stretch columns kept as a
static cross-check on the very same failure draws.  Link failures
sample the topology's realized (rack, switch) uplinks, never a random
rack pair (`faults.live_uplinks`).

Protocol (fluid drill): uniform all-to-all demand offered at LOAD of
each pair's direct-circuit capacity, injected over PACED cycle starts;
failures onset at cycle 2 with a hello-protocol detection lag; ToR
rows recover mid-run to exercise retry-on-recovery.  Retention is the
delivered fraction at one cycle past the paced window, relative to the
failure-free baseline row of the same batched call.

Run with --fast for the CI smoke variant (fluid acceptance rows only).
"""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import banner, check, save
from repro.core.routing import connectivity_loss, path_stretch
from repro.core.schedule import cycle_timing, slice_capacity_bytes
from repro.core.topology import build_opera_topology
from repro.netsim.faults import FailureSchedule, apply_flow_faults
from repro.netsim.fluid_jax import simulate_rotor_bulk_batch
from repro.netsim.sweep import DesignPoint

LOAD = 0.4          # fraction of per-pair direct capacity offered
PACED = 12          # cycles the demand is spread over
DETECT_LAG = 3      # slices until the hello protocol notices
LINK_FRACS = (0.02, 0.04, 0.08)
TOR_FRACS = (0.05, 0.07, 0.12)
SWITCH_COUNTS = (1, 2, 3)


def _schedules(topo, fast: bool):
    """The failure draws, one per batch row (row 0 = failure-free)."""
    S = topo.num_slices
    onset = 2 * S
    half = (PACED - 2) * S          # ToR recovery inside the paced window
    rows = [("baseline", FailureSchedule.empty(topo))]
    link_fracs = (0.04,) if fast else LINK_FRACS
    tor_fracs = () if fast else TOR_FRACS
    switch_counts = (2,) if fast else SWITCH_COUNTS
    for frac in link_fracs:
        rows.append((f"links {frac:.2f}", FailureSchedule.draw(
            topo, seed=11, link_frac=frac,
            onset_step=onset, detect_lag=DETECT_LAG)))
    for frac in tor_fracs:
        rows.append((f"tors {frac:.2f}", FailureSchedule.draw(
            topo, seed=13, tor_frac=frac,
            onset_step=onset, detect_lag=DETECT_LAG, recover_step=half)))
    for k in switch_counts:
        rows.append((f"switches {k}/6", FailureSchedule.draw(
            topo, seed=17, switch_count=k,
            onset_step=onset, detect_lag=DETECT_LAG)))
    return rows


def fluid_retention(cfg, topo, rows) -> dict:
    """One batched fluid call: every failure row + the baseline."""
    S = topo.num_slices
    cap = slice_capacity_bytes(cfg, cycle_timing(cfg))
    # each ordered pair has exactly u - 1 direct slices per cycle
    per_pair = LOAD * (cfg.u - 1) * cap * PACED
    demand = np.full((cfg.num_racks, cfg.num_racks), per_pair)
    np.fill_diagonal(demand, 0.0)
    r = simulate_rotor_bulk_batch(
        cfg,
        np.broadcast_to(demand, (len(rows), cfg.num_racks, cfg.num_racks)),
        topo=topo,
        max_cycles=PACED + 2,
        faults=[s for _, s in rows],
        paced_cycles=PACED,
    )
    T = (PACED + 1) * S - 1         # one cycle past the paced window
    base = float(r.finished_frac[0, T])
    out = {}
    for i, (label, _) in enumerate(rows):
        out[label] = dict(
            retention=float(r.finished_frac[i, T]) / base,
            blackholed_frac=float(r.blackholed_bytes[i] / r.total_bytes[i]),
            residual_frac=float(r.residual_bytes[i] / r.total_bytes[i]),
        )
        print(f"  {label:14s} retention {out[label]['retention']:.4f}  "
              f"blackholed {out[label]['blackholed_frac']:.5f}")
    return out


def flow_fct_inflation(topo) -> dict:
    """FCT inflation from the flow-level pair on the same fault axis."""
    from repro.netsim.flows import build_scenario
    from repro.netsim.flows_jax import simulate_flows_batch

    scn = build_scenario(
        "opera", "websearch", 0.25, num_hosts=216,
        horizon_s=0.4, dt_s=2e-4, tail_s=0.2, seed=0,
    )
    onset, lag = 300, 3             # dt ticks; schedule is unit-agnostic
    draws = [
        ("clean", None),
        ("links 0.04", FailureSchedule.draw(
            topo, seed=11, link_frac=0.04, onset_step=onset, detect_lag=lag)),
        ("tors 0.07", FailureSchedule.draw(
            topo, seed=13, tor_frac=0.07, onset_step=onset, detect_lag=lag,
            recover_step=1500)),
        ("switches 2/6", FailureSchedule.draw(
            topo, seed=17, switch_count=2, onset_step=onset, detect_lag=lag)),
    ]
    scns = [scn if s is None else apply_flow_faults(scn, s) for _, s in draws]
    batch = simulate_flows_batch(scns)
    base = batch.results[0]
    out = {}
    for (label, _), res in zip(draws, batch.results):
        out[label] = dict(
            fct_p99_ms_small=res.fct_p99_ms_small,
            fct_mean_ms=res.fct_mean_ms,
            finished_frac=res.finished_frac,
            p99_inflation=(res.fct_p99_ms_small
                           / max(base.fct_p99_ms_small, 1e-9)),
        )
        print(f"  {label:14s} p99(small) {res.fct_p99_ms_small:8.2f} ms  "
              f"x{out[label]['p99_inflation']:.2f}  "
              f"finished {res.finished_frac:.4f}")
    return out


def static_cross_check(topo, rows, fast: bool) -> dict:
    """Connectivity/stretch of the SAME draws — the old static columns."""
    stride = 8 if fast else 4
    slices = range(0, topo.num_slices, stride)
    out = {}
    for label, sched in rows:
        if sched.is_empty:
            continue
        fs = sched.to_failure_set()
        loss = connectivity_loss(topo, fs, slices)
        out[label] = dict(**loss)
        print(f"  {label:14s} worst-slice disc "
              f"{loss['worst_slice_disconnected_frac']:.4f}")
    base_st = path_stretch(topo, FailureSchedule.empty(topo).to_failure_set(),
                           list(slices)[:4])
    link_row = next((s for l, s in rows if l.startswith("links")), None)
    if link_row is not None:
        st = path_stretch(topo, link_row.to_failure_set(), list(slices)[:4])
        out["stretch"] = dict(baseline_mean_path=base_st["mean_path"],
                              failed_mean_path=st["mean_path"])
        print(f"  stretch: mean path {base_st['mean_path']:.2f} -> "
              f"{st['mean_path']:.2f} under link failures")
    return out


def run(fast: bool = False) -> dict:
    banner("Fig. 11 — measured degradation under link/ToR/switch failures"
           " (108 racks)")
    # design-time realization selected for 2-switch fault tolerance
    # (the paper's generate-and-test, §3.3 / Fig. 11c)
    topo = build_opera_topology(108, 6, seed=1, switch_fault_tolerance=2)
    cfg = DesignPoint(k=12, num_racks=108).to_config()
    rows = _schedules(topo, fast)

    print("-- fluid throughput retention (paced, one batched call)")
    fluid = fluid_retention(cfg, topo, rows)
    flows = {}
    if not fast:
        print("-- flow-level FCT inflation")
        flows = flow_fct_inflation(topo)
    print("-- static connectivity cross-check (same draws)")
    static = static_cross_check(topo, rows, fast)

    sw2 = "switches 2/6"
    ok1 = check("<= 10% throughput loss at ~4% link failures (paper)",
                fluid["links 0.04"]["retention"] >= 0.90,
                f"retention {fluid['links 0.04']['retention']:.4f}")
    ok2 = check("<= 10% throughput loss at 2/6 circuit switches (paper)",
                fluid[sw2]["retention"] >= 0.90,
                f"retention {fluid[sw2]['retention']:.4f}")
    ok3 = check("connectivity survives ~4% link failures (cross-check)",
                static["links 0.04"]["worst_slice_disconnected_frac"] < 0.01)
    ok4 = check("connectivity survives 2/6 switches (cross-check)",
                static[sw2]["worst_slice_disconnected_frac"] < 0.01)
    checks = dict(links_retention=ok1, switches_retention=ok2,
                  links_connectivity=ok3, switches_connectivity=ok4)
    if not fast:
        checks["degradation_beyond_budget"] = check(
            "3/6 switches degrades visibly (beyond the design budget)",
            fluid["switches 3/6"]["retention"] < fluid[sw2]["retention"] - 0.05)
        checks["stretch"] = check(
            "failures stretch paths (App. E)",
            static["stretch"]["failed_mean_path"]
            > static["stretch"]["baseline_mean_path"])
        fin_ratio = (flows["switches 2/6"]["finished_frac"]
                     / max(flows["clean"]["finished_frac"], 1e-9))
        checks["fct_inflation"] = check(
            "failures inflate small-flow FCT, service continues",
            flows["switches 2/6"]["p99_inflation"] >= 1.0
            and fin_ratio > 0.85,
            f"p99 x{flows['switches 2/6']['p99_inflation']:.2f}, "
            f"finished ratio {fin_ratio:.3f}")
    return dict(
        load=LOAD, paced_cycles=PACED, detect_lag=DETECT_LAG,
        fluid=fluid, flows=flows, static=static, checks=checks,
    )


if __name__ == "__main__":
    save("fig11_faults", run(fast="--fast" in sys.argv[1:]))
