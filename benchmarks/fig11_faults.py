"""Fig. 11 + App. E: fault tolerance of the 648-host Opera network."""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, check, save
from repro.core.routing import FailureSet, connectivity_loss, path_stretch
from repro.core.topology import build_opera_topology


def run() -> dict:
    banner("Fig. 11 — connectivity under link/ToR/switch failures (108 racks)")
    # design-time realization selected for 2-switch fault tolerance
    # (the paper's generate-and-test, §3.3 / Fig. 11c)
    topo = build_opera_topology(108, 6, seed=1, switch_fault_tolerance=2)
    rng = np.random.default_rng(0)
    slices = range(0, topo.num_slices, 4)
    n_links = 108 * 6 // 2  # rack-uplink pairs ~ one per live circuit

    out = {"links": [], "tors": [], "switches": []}
    for frac in (0.02, 0.04, 0.08):
        k = int(frac * n_links)
        fails = set()
        while len(fails) < k:
            a, b = rng.integers(0, 108, 2)
            if a != b:
                fails.add((min(a, b), max(a, b)))
        loss = connectivity_loss(topo, FailureSet(links=fails), slices)
        st = path_stretch(topo, FailureSet(links=fails), list(slices)[:6])
        out["links"].append(dict(frac=frac, **loss, **st))
        print(f"  links {frac:4.2f}: worst-slice disc "
              f"{loss['worst_slice_disconnected_frac']:.4f}  mean path "
              f"{st['mean_path']:.2f}")

    for frac in (0.05, 0.07, 0.12):
        k = max(1, int(frac * 108))
        tors = set(rng.choice(108, k, replace=False).tolist())
        loss = connectivity_loss(topo, FailureSet(tors=tors), slices)
        out["tors"].append(dict(frac=frac, **loss))
        print(f"  tors  {frac:4.2f}: worst-slice disc "
              f"{loss['worst_slice_disconnected_frac']:.4f}")

    for k in (1, 2, 3):
        loss = connectivity_loss(
            topo, FailureSet(switches=set(range(k))), slices
        )
        out["switches"].append(dict(count=k, frac=k / 6, **loss))
        print(f"  switches {k}/6: worst-slice disc "
              f"{loss['worst_slice_disconnected_frac']:.4f}")

    ok1 = check("~4% link failures tolerated (paper)",
                out["links"][1]["worst_slice_disconnected_frac"] < 0.01)
    ok2 = check("~7% ToR failures tolerated (paper)",
                out["tors"][1]["worst_slice_disconnected_frac"] < 0.01)
    ok3 = check("2/6 circuit switches tolerated (paper: 33%)",
                out["switches"][1]["worst_slice_disconnected_frac"] < 0.01)
    ok4 = check("failures stretch paths (App. E)",
                out["links"][-1]["mean_path"] > 3.0)
    out["checks"] = dict(links=ok1, tors=ok2, switches=ok3, stretch=ok4)
    return out


if __name__ == "__main__":
    save("fig11_faults", run())
