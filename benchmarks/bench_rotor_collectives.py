"""Framework-side benchmark: rotor-collective wire bytes vs theory.

Runs a subprocess with 8 fake XLA devices, compiles the rotor/XLA
collective variants, and compares measured per-device wire bytes
(loop-aware HLO accounting) against the closed-form schedule_stats —
the bandwidth-tax ledger of the TPU adaptation.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks.common import banner, check, save
from repro.core.collectives import schedule_stats

ROOT = Path(__file__).resolve().parents[1]

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, jax, jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import collectives as C
from repro.analysis.hlo_cost import analyze

mesh = compat.make_mesh((8,), ("d",))
N = 8
SZ = 1 << 14  # floats per shard

def wire(fn, shape):
    f = compat.shard_map(fn, mesh=mesh, in_specs=P("d"), out_specs=P("d"),
                      check_vma=False)
    spec = jax.ShapeDtypeStruct(shape, jnp.float32)
    hlo = jax.jit(f).lower(spec).compile().as_text()
    return analyze(hlo)["coll_bytes_total"]

out = {}
out["rotor_ar"] = wire(lambda x: C.rotor_all_reduce(x, "d"), (8 * N, SZ // N))
out["rotor_ar_direct"] = wire(
    lambda x: C.rotor_all_reduce(x, "d", mode="direct"), (8 * N, SZ // N))
out["xla_ar"] = wire(lambda x: lax.psum(x, "d"), (8 * N, SZ // N))
out["rotor_a2a"] = wire(lambda x: C.rotor_all_to_all(x[0], "d")[None],
                        (8, N, SZ // N))
out["rotor_a2a_vlb"] = wire(
    lambda x: C.rotor_all_to_all(x[0], "d", vlb=True)[None], (8, N, SZ // N))
out["xla_a2a"] = wire(
    lambda x: lax.all_to_all(x, "d", split_axis=0, concat_axis=0, tiled=True),
    (8 * N, SZ // N))
out["expander_ag_u3"] = wire(lambda x: C.expander_all_gather(x, "d", u=3),
                             (8, SZ // N))
out["xla_ag"] = wire(lambda x: lax.all_gather(x, "d"), (8, SZ // N))
out["payload_bytes"] = float(SZ * 4)
print(json.dumps(out))
"""


def run() -> dict:
    banner("Rotor collectives — measured wire bytes vs schedule theory (N=8)")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        print(r.stdout, r.stderr)
        raise RuntimeError("collective bench subprocess failed")
    meas = json.loads(r.stdout.strip().splitlines()[-1])
    th = schedule_stats(8, u=3)
    payload = meas["payload_bytes"]

    rows = []
    def row(name, measured, theory_ratio):
        ratio = measured / payload
        rows.append(dict(op=name, measured_bytes=measured,
                         measured_ratio=ratio, theory_ratio=theory_ratio))
        print(f"  {name:18s} {measured:12.3e} B  ratio {ratio:6.2f} "
              f"(theory {theory_ratio:.2f})")

    row("rotor_all_reduce", meas["rotor_ar"], th["rotor_ar_bytes"])
    row("rotor_ar_direct", meas["rotor_ar_direct"], th["rotor_ar_direct_bytes"])
    row("xla_psum", meas["xla_ar"], 2 * 7 / 8)
    row("rotor_all_to_all", meas["rotor_a2a"], th["rotor_a2a_bytes"])
    row("rotor_a2a_vlb", meas["rotor_a2a_vlb"], th["rotor_a2a_vlb_bytes"])
    row("xla_all_to_all", meas["xla_a2a"], 7 / 8)
    row("expander_ag_u3", meas["expander_ag_u3"],
        th["expander_allgather_bytes"])
    row("xla_all_gather", meas["xla_ag"], 7.0)

    ok1 = check("rotor A2A moves (N-1)/N of payload (one-hop direct, 0 tax)",
                abs(meas["rotor_a2a"] / payload - 7 / 8) < 0.15)
    ok2 = check("VLB exactly doubles wire bytes (100% tax, §3.4)",
                1.8 <= meas["rotor_a2a_vlb"] / meas["rotor_a2a"] <= 2.2)
    ok3 = check("latency-class all-gather pays the multi-hop tax",
                meas["expander_ag_u3"] > 1.5 * meas["xla_ag"])
    ok4 = check("rotor AR(rs+ag) within 2x of XLA psum wire bytes",
                meas["rotor_ar"] <= 2.0 * max(meas["xla_ar"], payload))
    return dict(rows=rows, theory=th,
                checks=dict(a2a=ok1, vlb=ok2, latency_tax=ok3, ar=ok4))


if __name__ == "__main__":
    save("bench_rotor_collectives", run())
