"""Fig. 4: path-length CDFs — 648-host Opera vs u=7 expander vs 3:1 Clos."""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, check, save
from repro.core.expander import (
    mean_max_path,
    path_length_cdf,
    random_regular_expander,
)
from repro.core.topology import build_opera_topology


def run() -> dict:
    banner("Fig. 4 — path length CDFs (648-host design point)")
    topo = build_opera_topology(108, 6, seed=0)
    # aggregate the CDF across a sample of slices
    cdfs = []
    maxes, means = [], []
    for t in range(0, topo.num_slices, 6):
        adj = topo.adjacency(t)
        cdfs.append(path_length_cdf(adj))
        m, mx, _ = mean_max_path(adj)
        means.append(m)
        maxes.append(mx)
    hmax = max(max(c) for c in cdfs)
    opera_cdf = {
        h: float(np.mean([c.get(h, 1.0) for c in cdfs]))
        for h in range(1, hmax + 1)
    }

    exp = random_regular_expander(130, 7, seed=1)
    exp_cdf = path_length_cdf(exp)
    exp_mean, exp_max, _ = mean_max_path(exp)

    # 3:1 folded Clos (12 pods x 9 racks): 2 ToR-ToR hops in-pod, 4 across
    same = 9 * 8 / (108 * 107)
    clos_cdf = {2: 12 * same, 4: 1.0}

    print(f"  opera : mean {np.mean(means):.2f}  max {max(maxes)}  cdf {opera_cdf}")
    print(f"  u=7 ex: mean {exp_mean:.2f}  max {exp_max}  cdf {exp_cdf}")
    print(f"  clos  : cdf {clos_cdf}")

    ok1 = check("Opera worst-case path <= 5-6 hops (paper: 5)", max(maxes) <= 6,
                f"max={max(maxes)}")
    ok2 = check("Opera only marginally longer than u=7 expander (paper)",
                np.mean(means) - exp_mean < 1.0,
                f"{np.mean(means):.2f} vs {exp_mean:.2f}")
    ok3 = check("Opera beats the Clos 4-hop cross-pod mass",
                opera_cdf.get(4, 1.0) > clos_cdf[2])
    return dict(
        opera_cdf=opera_cdf, opera_mean=float(np.mean(means)),
        opera_max=int(max(maxes)), expander_cdf=exp_cdf,
        expander_mean=exp_mean, clos_cdf=clos_cdf,
        checks=dict(max_path=ok1, near_expander=ok2, beats_clos=ok3),
    )


if __name__ == "__main__":
    save("fig04_path_lengths", run())
