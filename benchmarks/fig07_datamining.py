"""Fig. 7: Datamining FCT vs load — Opera admits 40 %, statics ~25 %."""
from __future__ import annotations

from benchmarks.common import banner, check, save
from repro.netsim.flows import simulate
from repro.netsim.workloads import byte_fraction_below


def run(loads=(0.01, 0.10, 0.25, 0.40)) -> dict:
    banner("Fig. 7 — Datamining workload, FCT vs load")
    out = {}
    for net in ("opera", "expander", "clos", "rotornet"):
        rows = []
        for load in loads:
            r = simulate(net, "datamining", load, horizon_s=1.6, seed=1)
            rows.append(dict(load=load, small_p99_ms=r.fct_p99_ms_small,
                             large_p99_ms=r.fct_p99_ms_large,
                             admitted=r.admitted,
                             finished=r.finished_frac))
            print(f"  {net:9s} load {load:4.2f}: small 99p "
                  f"{r.fct_p99_ms_small:9.3f} ms  large 99p "
                  f"{r.fct_p99_ms_large:9.1f} ms  admitted={r.admitted}")
        out[net] = rows

    frac = byte_fraction_below("datamining", 15e6)
    tax = frac * (3.34 - 1)  # §5.1: indirect bytes x (avg hops - 1)
    print(f"  effective bandwidth tax: {100*tax:.1f}% (paper: 8.4%)")
    ok1 = check("Opera admits 40% load (paper)", out["opera"][3]["admitted"])
    ok2 = check("static networks saturate by 40% (paper: ~25%)",
                not out["expander"][3]["admitted"] and not out["clos"][3]["admitted"])
    ok3 = check("effective tax ~8.4% (paper)", 0.05 <= tax <= 0.11,
                f"{100*tax:.1f}%")
    ok4 = check("RotorNet short-flow FCT is ms-scale (Fig. 7c: orders worse)",
                out["rotornet"][0]["small_p99_ms"] > 5.0
                and out["rotornet"][0]["small_p99_ms"] >
                8 * out["opera"][0]["small_p99_ms"])
    out["effective_tax"] = tax
    out["checks"] = dict(opera40=ok1, static_saturate=ok2, tax=ok3,
                         rotornet_latency=ok4)
    return out


if __name__ == "__main__":
    save("fig07_datamining", run())
