"""Fig. 7: Datamining FCT vs load — Opera admits 40 %, statics ~25 %.

The full (network x load x seed) grid runs through the batched JAX flow
engine as ONE device program (`sweep.run_flow_sweep`, auto/dense/tiled
dispatch); the saturation knees come from the batched-bisection
`flows.saturation_load` (two ladder calls per network).  Host count is
scaled down 3x from the paper's 648 — the per-host capacity fractions
that set the knees are size-invariant.
"""
from __future__ import annotations

from benchmarks.common import banner, check, save
from repro.netsim.flows import saturation_load
from repro.netsim.sweep import FlowSweepSpec, run_flow_sweep, summarize
from repro.netsim.workloads import byte_fraction_below

NETS = ("opera", "expander", "clos", "rotornet")
SIM_KW = dict(num_hosts=216, horizon_s=0.8, tail_s=0.4)


def run(loads=(0.01, 0.10, 0.25, 0.40), seeds=(1, 2),
        engine: str = "auto") -> dict:
    banner("Fig. 7 — Datamining workload, FCT vs load (batched JAX engine)")
    rows = run_flow_sweep(
        FlowSweepSpec(networks=NETS, workloads=("datamining",),
                      loads=tuple(loads), seeds=tuple(seeds), engine=engine),
        **SIM_KW)
    mean = summarize(
        rows,
        by=("network", "load"),
        stats=("fct_p99_ms_small", "fct_p99_ms_large", "admitted",
               "finished_frac", "backlog_frac"),
    )
    out = {}
    for net in NETS:
        out[net] = [r for r in mean if r["network"] == net]
        for r in out[net]:
            print(f"  {net:9s} load {r['load']:4.2f}: small 99p "
                  f"{r['fct_p99_ms_small']:9.3f} ms  large 99p "
                  f"{r['fct_p99_ms_large']:9.1f} ms  admitted={r['admitted']:.1f}")

    knees = {
        net: saturation_load(
            net, "datamining",
            ceiling=0.55, coarse_points=7, refine_points=4, seeds=(1,),
            engine=engine, num_hosts=162, horizon_s=0.8, tail_s=0.4,
        )
        for net in ("opera", "expander")
    }
    for net, k in knees.items():
        print(f"  saturation knee {net:9s}: {k.load:.3f}"
              f"{' (beyond grid)' if k.beyond_grid else ''}")

    frac = byte_fraction_below("datamining", 15e6)
    tax = frac * (3.34 - 1)  # §5.1: indirect bytes x (avg hops - 1)
    print(f"  effective bandwidth tax: {100*tax:.1f}% (paper: 8.4%)")

    last = len(loads) - 1
    ok1 = check("Opera admits 40% load (paper)",
                out["opera"][last]["admitted"] > 0.5)
    ok2 = check("static networks saturate by 40% (paper: ~25%)",
                out["expander"][last]["admitted"] < 0.5
                and out["clos"][last]["admitted"] < 0.5)
    ok3 = check("effective tax ~8.4% (paper)", 0.05 <= tax <= 0.11,
                f"{100*tax:.1f}%")
    ok4 = check("RotorNet short-flow FCT is ms-scale (Fig. 7c: orders worse)",
                out["rotornet"][0]["fct_p99_ms_small"] > 5.0
                and out["rotornet"][0]["fct_p99_ms_small"] >
                8 * out["opera"][0]["fct_p99_ms_small"])
    ok5 = check("saturation knee: opera above expander (paper: 40% vs 25%)",
                knees["opera"].load > knees["expander"].load,
                f"opera {knees['opera'].load:.2f} vs "
                f"expander {knees['expander'].load:.2f}")
    out["rows"] = rows
    out["effective_tax"] = tax
    out["saturation"] = {
        n: dict(load=k.load, beyond_grid=k.beyond_grid)
        for n, k in knees.items()
    }
    out["checks"] = dict(opera40=ok1, static_saturate=ok2, tax=ok3,
                         rotornet_latency=ok4, knees=ok5)
    return out


if __name__ == "__main__":
    save("fig07_datamining", run())
