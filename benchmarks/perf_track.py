"""PR-over-PR step-time tracking: dense vs permutation-sparse engine.

Measures the median per-step, per-scenario wall time of both fluid
engines at representative Appendix-B design points — the two paper-table
fabrics (k8-n16, k12-n108 at both group counts) and one k >= 32 point
the dense path never covered — and records them into the root-level
``BENCH_netsim.json`` with an append-only history keyed by commit, so
regressions in either engine show up as a diff in review.

Both engines run *truncated* slice sets (``SLICES_MEASURED`` steps) on
identical demand batches: step time is shape-stationary across a run, so
a short prefix measures the same thing as a full sweep while keeping the
dense (S, N, N) adjacency tractable at N = 432 (the full 432-slice
tensor is ~320 MB; 16 slices are ~12).  The truncated dense adjacency is
rebuilt from the index tensor rather than `matching_tensor()` for the
same reason.

``--fast`` skips timing entirely and runs the sparse-vs-dense parity
gate (full engine runs at the two small points, faulted and unfaulted)
— the mode `scripts/ci_tier1.sh` wires in; exits nonzero on drift.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

from benchmarks.common import banner, check, save
from repro.netsim.sweep import DesignPoint

REPO_ROOT = Path(__file__).resolve().parent.parent
BENCH_PATH = REPO_ROOT / "BENCH_netsim.json"

POINTS = (
    DesignPoint(k=8, num_racks=16, groups=1),
    DesignPoint(k=12, num_racks=108, groups=1),
    DesignPoint(k=12, num_racks=108, groups=2),
    DesignPoint(k=32, num_racks=432, groups=1),
    DesignPoint(k=32, num_racks=512, groups=2),
)
BATCH = 4
SLICES_MEASURED = 16
REPEATS = 7
# acceptance bar: at N >= this, sparse must beat dense by SPEEDUP_MIN
SPEEDUP_AT_RACKS = 432
SPEEDUP_MIN = 2.0


def _build_point(dp: DesignPoint):
    """Topology + truncated index/dense slice tensors + a demand batch."""
    from repro.core.topology import (
        build_lifted_opera_topology,
        build_opera_topology,
    )
    from repro.netsim.sweep import LIFTED_TOPO_RACKS, scenario_demand

    cfg = dp.to_config()
    if cfg.num_racks > LIFTED_TOPO_RACKS:
        topo = build_lifted_opera_topology(
            cfg.num_racks, cfg.u, seed=dp.topo_seed, groups=cfg.groups)
    else:
        topo = build_opera_topology(
            cfg.num_racks, cfg.u, seed=dp.topo_seed, groups=cfg.groups)
    s = min(SLICES_MEASURED, topo.num_slices)
    dst = topo.matching_index_tensor()[:s]            # (s, N, u)
    n = cfg.num_racks
    adj = np.zeros((s, n, n), np.float32)
    t_idx, i_idx, s_idx = np.nonzero(dst < n)
    adj[t_idx, i_idx, dst[t_idx, i_idx, s_idx]] = 1.0
    demands = np.stack([
        scenario_demand("permutation", cfg, 0.3, seed) for seed in range(BATCH)
    ])
    return cfg, dst, adj, demands


def measure_point(dp: DesignPoint) -> dict:
    import jax.numpy as jnp

    from repro.core.schedule import cycle_timing, slice_capacity_bytes
    from repro.netsim.fluid_jax import _run_batch, _run_batch_sparse

    cfg, dst, adj, demands = _build_point(dp)
    cap = slice_capacity_bytes(cfg, cycle_timing(cfg))
    own0 = jnp.asarray(demands / cap, jnp.float32)
    adj_j = jnp.asarray(adj)
    dst_j = jnp.asarray(dst)
    s = dst.shape[0]

    def run_dense():
        _run_batch(adj_j, own0, True, 1)[2].block_until_ready()

    def run_sparse():
        _run_batch_sparse(dst_j, own0, True, 1)[2].block_until_ready()

    # Interleave the two engines within each round so clock drift and
    # cache/allocator state hit both equally; the speedup is the median
    # of per-round ratios, not a ratio of medians.
    run_dense(), run_sparse()              # warmup / compile
    dense_t, sparse_t = [], []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        run_dense()
        dense_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_sparse()
        sparse_t.append(time.perf_counter() - t0)
    scale = 1e6 / s / BATCH
    ratios = [d / sp for d, sp in zip(dense_t, sparse_t)]
    return dict(
        num_racks=dp.num_racks, k=dp.k, groups=dp.groups,
        slices_measured=s, batch=BATCH,
        dense_us=round(float(np.median(dense_t)) * scale, 1),
        sparse_us=round(float(np.median(sparse_t)) * scale, 1),
        speedup=round(float(np.median(ratios)), 2),
    )


def _git_head() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except Exception:  # noqa: BLE001
        return "unknown"


def _record(points: dict) -> dict:
    doc = dict(updated="", points={}, history=[])
    if BENCH_PATH.exists():
        try:
            doc = json.loads(BENCH_PATH.read_text())
        except json.JSONDecodeError:
            pass
    stamp = time.strftime("%Y-%m-%d")
    doc["updated"] = stamp
    doc["points"] = points
    doc.setdefault("history", []).append(
        dict(commit=_git_head(), date=stamp, points=points))
    BENCH_PATH.write_text(json.dumps(doc, indent=1) + "\n")
    return doc


def parity_gate(tol: float = 1e-5) -> bool:
    """Full-engine sparse-vs-dense agreement at the small paper points,
    faulted and unfaulted — the CI drift gate."""
    from repro.core.topology import build_opera_topology
    from repro.netsim.faults import FailureEvent, FailureSchedule
    from repro.netsim.fluid_jax import simulate_rotor_bulk_batch
    from repro.netsim.sweep import scenario_demand

    ok = True
    for dp in (DesignPoint(k=8, num_racks=16, groups=1),
               DesignPoint(k=8, num_racks=16, groups=2)):
        cfg = dp.to_config()
        topo = build_opera_topology(
            cfg.num_racks, cfg.u, seed=0, groups=cfg.groups)
        # overloaded skew: the run must NOT complete, so residual / wire
        # trajectories exercise the VLB spread math, not just the totals
        demands = np.stack([
            scenario_demand("skew", cfg, 2.5, s) for s in range(2)])
        faults = FailureSchedule(
            num_racks=cfg.num_racks, num_switches=cfg.u,
            events=(FailureEvent("link", ((1, 0),), onset_step=1,
                                 detect_lag=2, recover_step=9),
                    FailureEvent("tor", (3,), onset_step=2,
                                 detect_lag=1, recover_step=11)))
        for fs in (None, faults):
            res = {}
            for engine in ("dense", "sparse"):
                res[engine] = simulate_rotor_bulk_batch(
                    cfg, demands, vlb=True, max_cycles=8, topo=topo,
                    faults=fs, engine=engine)
            for field in ("goodput_bytes", "wire_bytes", "residual_bytes"):
                a = getattr(res["dense"], field)
                b = getattr(res["sparse"], field)
                drift = float(np.max(
                    np.abs(a - b) / np.maximum(np.abs(a), 1.0)))
                ok &= check(
                    f"{dp.name} {'faulted' if fs else 'clean'} {field} "
                    f"drift < {tol}", drift < tol, f"{drift:.2e}")
    return ok


def run(fast: bool = False) -> dict:
    banner("Engine perf tracking — dense vs permutation-sparse step time")
    if fast:
        ok = parity_gate()
        return dict(mode="fast", checks=dict(parity=ok))

    points = {}
    for dp in POINTS:
        r = measure_point(dp)
        points[dp.name] = r
        print(f"  {dp.name:14s} dense={r['dense_us']:8.1f} us/step/scn  "
              f"sparse={r['sparse_us']:8.1f}  speedup={r['speedup']:.2f}x")
    doc = _record(points)
    print(f"  recorded -> {BENCH_PATH.relative_to(REPO_ROOT)} "
          f"(history: {len(doc['history'])} entries)")

    big = [r for r in points.values() if r["num_racks"] >= SPEEDUP_AT_RACKS]
    ok_speed = check(
        f"sparse >= {SPEEDUP_MIN}x dense at N >= {SPEEDUP_AT_RACKS}",
        bool(big) and all(r["speedup"] >= SPEEDUP_MIN for r in big),
        ", ".join(f"N={r['num_racks']}: {r['speedup']:.2f}x" for r in big))
    ok_parity = parity_gate()
    return dict(points=points, checks=dict(speedup=ok_speed,
                                           parity=ok_parity))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="parity gate only, no timing (CI mode)")
    args = ap.parse_args(argv)
    out = run(fast=args.fast)
    if not args.fast:
        save("perf_track", out)
    if not all(out["checks"].values()):
        sys.exit(1)


if __name__ == "__main__":
    main()
