"""PR-over-PR step-time tracking for both perf-tracked hot paths:
the rotor engines (dense vs permutation-sparse) and the flow engines
(dense vs tiled-streaming).

Rotor section: measures the median per-step, per-scenario wall time of
both fluid engines at representative Appendix-B design points — the two
paper-table fabrics (k8-n16, k12-n108 at both group counts) and one
k >= 32 point the dense path never covered — and records them into the
root-level ``BENCH_netsim.json`` with an append-only history keyed by
commit, so regressions in either engine show up as a diff in review.

Both rotor engines run *truncated* slice sets (``SLICES_MEASURED``
steps) on identical demand batches: step time is shape-stationary
across a run, so a short prefix measures the same thing as a full sweep
while keeping the dense (S, N, N) adjacency tractable at N = 432 (the
full 432-slice tensor is ~320 MB; 16 slices are ~12).  The truncated
dense adjacency is rebuilt from the index tensor rather than
`matching_tensor()` for the same reason.

Flow section: measures dense-vs-tiled per-step wall time and peak
device flow state on synthetic short-flow streams (``FLOW_SIZES``
flows over ``FLOW_STEPS`` fixed-dt steps) and records them into
``BENCH_flows.json`` with the same commit-keyed history.  Dense
per-step time comes from differencing two truncated-horizon runs (the
same shape-stationarity argument; differencing cancels host staging),
tiled from a full end-to-end run including its host chunk loop.

``--fast`` skips timing entirely and runs both parity gates — the
sparse-vs-dense rotor gate and the tiled-vs-dense flow gate (full
engine runs at small points, faulted and unfaulted) — the mode
`scripts/ci_tier1.sh` wires in; exits nonzero on drift.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

from benchmarks.common import banner, check, save
from repro.netsim.sweep import DesignPoint

REPO_ROOT = Path(__file__).resolve().parent.parent
BENCH_PATH = REPO_ROOT / "BENCH_netsim.json"
BENCH_FLOWS_PATH = REPO_ROOT / "BENCH_flows.json"

POINTS = (
    DesignPoint(k=8, num_racks=16, groups=1),
    DesignPoint(k=12, num_racks=108, groups=1),
    DesignPoint(k=12, num_racks=108, groups=2),
    DesignPoint(k=32, num_racks=432, groups=1),
    DesignPoint(k=32, num_racks=512, groups=2),
)
BATCH = 4
SLICES_MEASURED = 16
REPEATS = 7
# acceptance bar: at N >= this, sparse must beat dense by SPEEDUP_MIN
SPEEDUP_AT_RACKS = 432
SPEEDUP_MIN = 2.0

# flow-engine section: synthetic short-flow streams of this many flows
# over FLOW_STEPS steps; dense per-step time is differenced between
# runs truncated to FLOW_DENSE_STEPS
FLOW_SIZES = (32768, 131072, 393216)
FLOW_STEPS = 1500
FLOW_DENSE_STEPS = (150, 450)
FLOW_REPEATS = 5
# acceptance bar: at the largest size, tiled must beat dense 2x in
# step time OR peak device flow state
FLOW_WIN_MIN = 2.0


def _build_point(dp: DesignPoint):
    """Topology + truncated index/dense slice tensors + a demand batch."""
    from repro.core.topology import (
        build_lifted_opera_topology,
        build_opera_topology,
    )
    from repro.netsim.sweep import LIFTED_TOPO_RACKS, scenario_demand

    cfg = dp.to_config()
    if cfg.num_racks > LIFTED_TOPO_RACKS:
        topo = build_lifted_opera_topology(
            cfg.num_racks, cfg.u, seed=dp.topo_seed, groups=cfg.groups)
    else:
        topo = build_opera_topology(
            cfg.num_racks, cfg.u, seed=dp.topo_seed, groups=cfg.groups)
    s = min(SLICES_MEASURED, topo.num_slices)
    dst = topo.matching_index_tensor()[:s]            # (s, N, u)
    n = cfg.num_racks
    adj = np.zeros((s, n, n), np.float32)
    t_idx, i_idx, s_idx = np.nonzero(dst < n)
    adj[t_idx, i_idx, dst[t_idx, i_idx, s_idx]] = 1.0
    demands = np.stack([
        scenario_demand("permutation", cfg, 0.3, seed) for seed in range(BATCH)
    ])
    return cfg, dst, adj, demands


def measure_point(dp: DesignPoint) -> dict:
    import jax.numpy as jnp

    from repro.core.schedule import cycle_timing, slice_capacity_bytes
    from repro.netsim.fluid_jax import _run_batch, _run_batch_sparse

    cfg, dst, adj, demands = _build_point(dp)
    cap = slice_capacity_bytes(cfg, cycle_timing(cfg))
    own0 = jnp.asarray(demands / cap, jnp.float32)
    adj_j = jnp.asarray(adj)
    dst_j = jnp.asarray(dst)
    s = dst.shape[0]

    def run_dense():
        _run_batch(adj_j, own0, True, 1)[2].block_until_ready()

    def run_sparse():
        _run_batch_sparse(dst_j, own0, True, 1)[2].block_until_ready()

    # Interleave the two engines within each round so clock drift and
    # cache/allocator state hit both equally; the speedup is the median
    # of per-round ratios, not a ratio of medians.
    run_dense(), run_sparse()              # warmup / compile
    dense_t, sparse_t = [], []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        run_dense()
        dense_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_sparse()
        sparse_t.append(time.perf_counter() - t0)
    scale = 1e6 / s / BATCH
    ratios = [d / sp for d, sp in zip(dense_t, sparse_t)]
    return dict(
        num_racks=dp.num_racks, k=dp.k, groups=dp.groups,
        slices_measured=s, batch=BATCH,
        dense_us=round(float(np.median(dense_t)) * scale, 1),
        sparse_us=round(float(np.median(sparse_t)) * scale, 1),
        speedup=round(float(np.median(ratios)), 2),
    )


def _git_head() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except Exception:  # noqa: BLE001
        return "unknown"


def _record(points: dict, path: Path = BENCH_PATH) -> dict:
    doc = dict(updated="", points={}, history=[])
    if path.exists():
        try:
            doc = json.loads(path.read_text())
        except json.JSONDecodeError:
            pass
    stamp = time.strftime("%Y-%m-%d")
    doc["updated"] = stamp
    doc["points"] = points
    doc.setdefault("history", []).append(
        dict(commit=_git_head(), date=stamp, points=points))
    path.write_text(json.dumps(doc, indent=1) + "\n")
    return doc


def parity_gate(tol: float = 1e-5) -> bool:
    """Full-engine sparse-vs-dense agreement at the small paper points,
    faulted and unfaulted — the CI drift gate."""
    from repro.core.topology import build_opera_topology
    from repro.netsim.faults import FailureEvent, FailureSchedule
    from repro.netsim.fluid_jax import simulate_rotor_bulk_batch
    from repro.netsim.sweep import scenario_demand

    ok = True
    for dp in (DesignPoint(k=8, num_racks=16, groups=1),
               DesignPoint(k=8, num_racks=16, groups=2)):
        cfg = dp.to_config()
        topo = build_opera_topology(
            cfg.num_racks, cfg.u, seed=0, groups=cfg.groups)
        # overloaded skew: the run must NOT complete, so residual / wire
        # trajectories exercise the VLB spread math, not just the totals
        demands = np.stack([
            scenario_demand("skew", cfg, 2.5, s) for s in range(2)])
        faults = FailureSchedule(
            num_racks=cfg.num_racks, num_switches=cfg.u,
            events=(FailureEvent("link", ((1, 0),), onset_step=1,
                                 detect_lag=2, recover_step=9),
                    FailureEvent("tor", (3,), onset_step=2,
                                 detect_lag=1, recover_step=11)))
        for fs in (None, faults):
            res = {}
            for engine in ("dense", "sparse"):
                res[engine] = simulate_rotor_bulk_batch(
                    cfg, demands, vlb=True, max_cycles=8, topo=topo,
                    faults=fs, engine=engine)
            for field in ("goodput_bytes", "wire_bytes", "residual_bytes"):
                a = getattr(res["dense"], field)
                b = getattr(res["sparse"], field)
                drift = float(np.max(
                    np.abs(a - b) / np.maximum(np.abs(a), 1.0)))
                ok &= check(
                    f"{dp.name} {'faulted' if fs else 'clean'} {field} "
                    f"drift < {tol}", drift < tol, f"{drift:.2e}")
    return ok


def _stream_scenario(num_flows: int, num_steps: int = FLOW_STEPS,
                     seed: int = 0):
    """Synthetic mostly-short-flow stream: `num_flows` Poisson-ish
    arrivals over 80% of the horizon, lognormal sizes with a clipped
    heavy tail (all three FCT classes populated), single latency pool
    provisioned at 1.5x the offered rate — the admitted regime the
    tiled engine targets, where the concurrently-active population is a
    sliver of the lifetime flow count."""
    from repro.netsim.flows import FlowScenario

    dt_s = 1e-3
    horizon_s = 0.8 * num_steps * dt_s
    tail_s = 0.2 * num_steps * dt_s
    link_gbps = 10.0
    unit = link_gbps * 1e9 / 8.0 * dt_s          # bytes per NIC-step
    rng = np.random.default_rng(seed)
    arr = np.sort(rng.uniform(0.0, horizon_s, num_flows))
    sizes = np.clip(
        rng.lognormal(mean=np.log(0.3 * unit), sigma=1.5, size=num_flows),
        1e3, 30.0 * unit)
    offered_Bps = sizes.sum() / horizon_s
    return FlowScenario(
        network="synthetic", workload="stream", load=0.0, seed=seed,
        horizon_s=horizon_s, dt_s=dt_s, tail_s=tail_s,
        num_hosts=1, link_gbps=link_gbps,
        arr=arr, sizes=sizes,
        start_step=np.ceil(arr / dt_s).astype(np.int32),
        is_bulk=np.zeros(num_flows, bool),
        lat_pool_Bps=float(1.5 * offered_Bps), bulk_pool_Bps=0.0,
    )


def measure_flow_point(num_flows: int) -> dict:
    import dataclasses

    from repro.netsim.flows_jax import (
        DEFAULT_TILE,
        dense_state_bytes,
        simulate_flows_batch,
        tiled_state_bytes,
    )

    scn = _stream_scenario(num_flows)

    # dense per-step time by differencing two truncated horizons: the
    # per-step cost is shape-stationary, and the difference cancels the
    # O(n) host staging both runs pay.
    def dense_run(steps):
        trunc = dataclasses.replace(
            scn, horizon_s=steps * scn.dt_s, tail_s=0.0)
        simulate_flows_batch([trunc], engine="dense")

    s_lo, s_hi = FLOW_DENSE_STEPS
    dense_run(s_lo), dense_run(s_hi)           # warmup / compile
    dense_t = []
    for _ in range(FLOW_REPEATS):
        t0 = time.perf_counter()
        dense_run(s_lo)
        t_lo = time.perf_counter() - t0
        t0 = time.perf_counter()
        dense_run(s_hi)
        t_hi = time.perf_counter() - t0
        dense_t.append((t_hi - t_lo) / (s_hi - s_lo))

    # tiled end-to-end over the full horizon, host chunk loop included
    def tiled_run():
        return simulate_flows_batch([scn], engine="tiled")

    res = tiled_run()                          # warmup / compile
    tiled_t = []
    for _ in range(FLOW_REPEATS):
        t0 = time.perf_counter()
        tiled_run()
        tiled_t.append((time.perf_counter() - t0) / scn.steps)

    dense_us = float(np.median(dense_t)) * 1e6
    tiled_us = float(np.median(tiled_t)) * 1e6
    dense_b = dense_state_bytes(num_flows)
    tiled_b = tiled_state_bytes(res.peak_window_tiles, DEFAULT_TILE)
    return dict(
        num_flows=num_flows, steps=scn.steps,
        tile=DEFAULT_TILE, peak_window_tiles=res.peak_window_tiles,
        dense_us_step=round(dense_us, 1),
        tiled_us_step=round(tiled_us, 1),
        speedup=round(dense_us / tiled_us, 2),
        dense_state_mb=round(dense_b / 1e6, 2),
        tiled_state_mb=round(tiled_b / 1e6, 2),
        state_ratio=round(dense_b / tiled_b, 2),
    )


def flow_parity_gate() -> bool:
    """Tiled-vs-dense flow-engine agreement — full runs on small grids,
    clean and faulted, with deliberately tiny tiles so the windowing
    and capacity-growth machinery is exercised.  Histograms must match
    bitwise (the engines share the binning math); deficit snapshots to
    f32 reduction-order tolerance; streamed percentiles within one
    histogram bin of the dense engine's exact ones."""
    from repro.netsim.faults import FailureEvent, FailureSchedule, apply_flow_faults
    from repro.netsim.flows import FCT_BIN_LOG2_WIDTH, build_scenario
    from repro.netsim.flows_jax import simulate_flows_batch

    kw = dict(num_hosts=16, horizon_s=0.12, dt_s=5e-4, tail_s=0.1)
    scns = [
        build_scenario("opera", "websearch", 0.1, seed=0, **kw),
        build_scenario("opera", "datamining", 0.35, seed=1, **kw),
        build_scenario("expander", "websearch", 0.2, seed=2, **kw),
        build_scenario("rotornet", "websearch", 0.15, seed=3, **kw),
    ]
    sched = FailureSchedule(
        num_racks=8, num_switches=2, seed=5,
        events=(FailureEvent("tor", (1,), onset_step=20, detect_lag=10,
                             recover_step=120),
                FailureEvent("switch", (0,), onset_step=40, detect_lag=8,
                             recover_step=200)))
    ok = True
    for label, batch in (
        ("clean", scns),
        ("faulted", [apply_flow_faults(s, sched) for s in scns[:2]] + scns[2:]),
    ):
        dense = simulate_flows_batch(batch, engine="dense")
        tiled = simulate_flows_batch(batch, engine="tiled", tile_size=64,
                                     window_tiles=2, chunk_steps=48)
        hist_ok = all(np.array_equal(d, t)
                      for d, t in zip(dense.hists, tiled.hists))
        ok &= check(f"flow {label}: histograms bitwise equal", hist_ok)
        drift = max(
            abs(d.backlog_frac - t.backlog_frac)
            for d, t in zip(dense.results, tiled.results))
        ok &= check(f"flow {label}: deficit drift < 1e-5", drift < 1e-5,
                    f"{drift:.2e}")
        fin_ok = all(d.finished_frac == t.finished_frac
                     for d, t in zip(dense.results, tiled.results))
        ok &= check(f"flow {label}: finished_frac exact", fin_ok)
        bins_off = 0.0
        for d, t in zip(dense.results, tiled.results):
            for f in ("fct_p99_ms_small", "fct_p99_ms_mid",
                      "fct_p99_ms_large"):
                dv, tv = getattr(d, f), getattr(t, f)
                if dv > 0 and np.isfinite(dv):
                    bins_off = max(
                        bins_off,
                        abs(np.log2(tv / dv)) / FCT_BIN_LOG2_WIDTH)
                else:
                    ok &= check(f"flow {label}: {f} sentinel match",
                                dv == tv, f"{dv} vs {tv}")
        ok &= check(f"flow {label}: p99s within one histogram bin",
                    bins_off <= 1.0, f"{bins_off:.2f} bins")
        rem_ok = all(
            np.allclose(d, t, rtol=1e-5, atol=1.0)
            for d, t in zip(dense.remaining_bytes, tiled.remaining_bytes))
        ok &= check(f"flow {label}: remaining bytes close", rem_ok)
    return ok


def run(fast: bool = False) -> dict:
    banner("Engine perf tracking — dense vs permutation-sparse step time")
    if fast:
        ok = parity_gate()
        ok_flow = flow_parity_gate()
        return dict(mode="fast", checks=dict(parity=ok, flow_parity=ok_flow))

    points = {}
    for dp in POINTS:
        r = measure_point(dp)
        points[dp.name] = r
        print(f"  {dp.name:14s} dense={r['dense_us']:8.1f} us/step/scn  "
              f"sparse={r['sparse_us']:8.1f}  speedup={r['speedup']:.2f}x")
    doc = _record(points)
    print(f"  recorded -> {BENCH_PATH.relative_to(REPO_ROOT)} "
          f"(history: {len(doc['history'])} entries)")

    big = [r for r in points.values() if r["num_racks"] >= SPEEDUP_AT_RACKS]
    ok_speed = check(
        f"sparse >= {SPEEDUP_MIN}x dense at N >= {SPEEDUP_AT_RACKS}",
        bool(big) and all(r["speedup"] >= SPEEDUP_MIN for r in big),
        ", ".join(f"N={r['num_racks']}: {r['speedup']:.2f}x" for r in big))
    ok_parity = parity_gate()

    banner("Flow engine perf tracking — dense vs tiled streaming")
    fpoints = {}
    for n in FLOW_SIZES:
        r = measure_flow_point(n)
        fpoints[f"n{n}"] = r
        print(f"  n={n:<8d} dense={r['dense_us_step']:8.1f} us/step  "
              f"tiled={r['tiled_us_step']:8.1f}  "
              f"speedup={r['speedup']:.2f}x  "
              f"state {r['dense_state_mb']:.1f} -> {r['tiled_state_mb']:.1f} "
              f"MB ({r['state_ratio']:.1f}x)")
    fdoc = _record(fpoints, BENCH_FLOWS_PATH)
    print(f"  recorded -> {BENCH_FLOWS_PATH.relative_to(REPO_ROOT)} "
          f"(history: {len(fdoc['history'])} entries)")

    largest = fpoints[f"n{max(FLOW_SIZES)}"]
    ok_flow_win = check(
        f"tiled >= {FLOW_WIN_MIN}x dense (step time or state) at "
        f"n={max(FLOW_SIZES)}",
        largest["speedup"] >= FLOW_WIN_MIN
        or largest["state_ratio"] >= FLOW_WIN_MIN,
        f"speedup={largest['speedup']:.2f}x, "
        f"state={largest['state_ratio']:.2f}x")
    ok_flow_parity = flow_parity_gate()
    return dict(points=points, flow_points=fpoints,
                checks=dict(speedup=ok_speed, parity=ok_parity,
                            flow_win=ok_flow_win,
                            flow_parity=ok_flow_parity))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="parity gate only, no timing (CI mode)")
    args = ap.parse_args(argv)
    out = run(fast=args.fast)
    if not args.fast:
        save("perf_track", out)
    if not all(out["checks"].values()):
        sys.exit(1)


if __name__ == "__main__":
    main()
