"""Whole-grid scenario sweep through the batched JAX fluid engine.

Exercises the path the bulk figures ride: per design point, the full
(workload x load x seed) grid is simulated in ONE vmapped/jitted call
(16 scenarios per design here).  Checks the physical invariants the
engine must honor across the grid — this is the benchmark-level analogue
of tests/test_netsim_jax.py run at sweep scale.
"""
from __future__ import annotations

import time

from benchmarks.common import banner, check, save
from repro.netsim.sweep import DesignPoint, SweepSpec, run_sweep, summarize

# The Appendix-B scale point the dense engine never swept: k = 32 means a
# (num_slices, N, N) matching tensor of ~320 MB, while the sparse
# engine's (num_slices, N, u) index form is ~11 MB.  Few cycles: the
# point of this stage is grid *reach* (topology lift + sparse engine at
# N = 432), not completion; conservation is the invariant checked.
BIG_POINT = DesignPoint(k=32, num_racks=432, groups=1)
BIG_CYCLES = 4


def run() -> dict:
    banner("Scenario sweep — batched fluid engine over a design grid")
    spec = SweepSpec(
        designs=(
            DesignPoint(k=8, num_racks=16),
            DesignPoint(k=8, num_racks=16, groups=2),
        ),
        workloads=("shuffle", "permutation", "skew", "hotrack"),
        loads=(0.2, 0.6),
        seeds=(0, 1),
        max_cycles=80,
    )
    t0 = time.time()
    rows = run_sweep(spec)
    dt = time.time() - t0
    summary = summarize(rows)
    for s in summary:
        print(f"  {s['design']:12s} {s['workload']:11s} load={s['load']:.1f} "
              f"fct99={s['fct_99_ms']:8.3f} ms  tput={s['throughput_frac']:.3f} "
              f"tax={s['bandwidth_tax']:.2f}  fin={s['finished_frac']:.4f}")
    print(f"  {len(rows)} scenarios ({spec.scenarios_per_design}/design "
          f"vmapped) in {dt:.1f}s")

    ok1 = check("16 scenarios per design in one vmapped call",
                spec.scenarios_per_design == 16)
    ok2 = check("every scenario delivered its demand",
                all(r["finished_frac"] >= 0.999 for r in rows))
    ok3 = check("bandwidth tax is never negative",
                all(r["bandwidth_tax"] >= -1e-6 for r in rows))
    by_key = {}
    for r in rows:
        by_key.setdefault(
            (r["design"], r["workload"], r["seed"]), []
        ).append((r["load"], r["fct_99_ms"]))
    mono = all(
        a[1] <= b[1] + 1e-9
        for v in by_key.values()
        for a, b in zip(sorted(v), sorted(v)[1:])
    )
    ok4 = check("completion time monotone in load per scenario", mono)
    grouped = [r for r in rows if r["groups"] == 2]
    ungrouped = [r for r in rows if r["groups"] == 1]
    ok5 = check(
        "grouped reconfiguration halves the cycle (App. B)",
        grouped[0]["cycle_ms"] < 0.6 * ungrouped[0]["cycle_ms"],
        f"{grouped[0]['cycle_ms']:.2f} vs {ungrouped[0]['cycle_ms']:.2f} ms",
    )

    banner(f"Appendix-B scale point {BIG_POINT.name} — sparse engine")
    big_spec = SweepSpec(
        designs=(BIG_POINT,),
        workloads=("permutation",),
        loads=(0.3,),
        seeds=(0,),
        max_cycles=BIG_CYCLES,
        engine="sparse",
    )
    t0 = time.time()
    big_rows, big_res = [], None
    for dp in big_spec.designs:
        from repro.netsim.sweep import run_design
        r, big_res = run_design(big_spec, dp)
        big_rows.extend(r)
    big_dt = time.time() - t0
    for r in big_rows:
        print(f"  {r['design']:14s} {r['workload']:11s} "
              f"fin={r['finished_frac']:.3f} tax={r['bandwidth_tax']:.2f} "
              f"({big_dt:.1f}s, {r['slices_run']} slices)")
    import numpy as np
    conserved = float(np.max(np.abs(
        big_res.goodput_bytes + big_res.residual_bytes - big_res.total_bytes
    ) / big_res.total_bytes))
    ok6 = check(f"k>=32 sparse point conserves bytes ({BIG_POINT.name})",
                conserved < 1e-4, f"rel err {conserved:.2e}")
    ok7 = check("k>=32 sparse point makes forward progress",
                all(r["finished_frac"] > 0.1 for r in big_rows))
    return dict(rows=rows, summary=summary, wall_s=dt,
                big_rows=big_rows, big_wall_s=big_dt,
                checks=dict(batch=ok1, finished=ok2, tax=ok3, monotone=ok4,
                            groups=ok5, big_conserved=ok6,
                            big_progress=ok7))


if __name__ == "__main__":
    save("netsim_sweep", run())
