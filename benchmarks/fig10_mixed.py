"""Fig. 10: mixed Websearch(latency)+Shuffle(bulk) — aggregate throughput.

Three views of the same figure:

* the calibrated analytic capacity model (netsim/capacity.py), which
  carries the paper's transport efficiencies and drives the checks;
* a fluid *measurement* from the batched JAX bulk engine: all
  Websearch-load points simulated in ONE vmapped call, each scenario a
  saturating shuffle on a fabric derated by the latency class's slot
  consumption (x * avg_hops of the duty-cycled uplink slots).  The
  fluid engine has ideal transport, so the measured bulk capacity
  should sit slightly above the eta-calibrated model;
* a flow-level *measurement* from the batched JAX flow engine: each
  scenario offers real Websearch flows at load x on the latency pool
  plus saturating >=15 MB bulk flows on the slot-derated direct-circuit
  pool (one vmapped call for all x), and the aggregate served
  throughput is read off the remaining-bytes tensor at the horizon —
  an end-to-end check that the processor-sharing engine reproduces the
  same aggregate-capacity curve.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, check, save
from repro.configs.opera_paper import OPERA_648
from repro.netsim.capacity import (
    CLOS_648_PT,
    EXPANDER_650_PT,
    OPERA_648_PT,
    bulk_capacity_opera,
    clos_capacity,
    latency_capacity,
)
from repro.netsim.flows import build_mixed_scenario
from repro.netsim.flows_jax import simulate_flows_batch
from repro.netsim.fluid_jax import simulate_rotor_bulk_batch
from repro.netsim.workloads import demand_all_to_all


def _measured_bulk_frac(x_adms) -> list:
    """Fluid bulk capacity (fraction of host bw) left at each ws load.

    One batched call: scenario i runs the shuffle against a fabric whose
    links are scaled by s_i (the slot fraction the latency class leaves).
    Scaling capacity by s at fixed demand == scaling demand by 1/s at
    fixed capacity, and throughput scales back by s — so a single shared
    topology/capacity serves every scenario.
    """
    op = OPERA_648_PT
    slots = op.duty * op.u / op.d
    scales = np.array(
        [max(1.0 - x * op.avg_hops / slots, 0.05) for x in x_adms]
    )
    n, d = OPERA_648.num_racks, OPERA_648.hosts_per_rack
    # 3 cycles of backlog per host: saturating, horizon-bound measurement
    base = demand_all_to_all(n, d, 3.0 * _cycle_bytes_per_host() / ((n - 1) * d))
    demands = np.stack([base / s for s in scales])
    res = simulate_rotor_bulk_batch(
        OPERA_648, demands, vlb=False, max_cycles=8
    )
    host_bw = OPERA_648.num_hosts * OPERA_648.link_rate_gbps
    return [float(s * t / host_bw) for s, t in zip(scales, res.throughput_gbps)]


def _cycle_bytes_per_host() -> float:
    from repro.core.schedule import cycle_timing

    t = cycle_timing(OPERA_648)
    return OPERA_648.link_rate_gbps * 1e9 / 8 * t.cycle_ms * 1e-3


def _flow_measured_total(x_adms, num_hosts=216, horizon_s=0.5, seed=5,
                         engine: str = "auto") -> list:
    """Aggregate served throughput (fraction of host bw) from the flow
    engine: one vmapped call over every Websearch-load point, each a
    mixed scenario with the bulk class offered 1.3x the slot-derated
    direct capacity (saturating)."""
    op = OPERA_648_PT
    slots = op.duty * op.u / op.d
    scns = [
        build_mixed_scenario(
            x,
            bulk_load=1.3 * max(0.9 * (slots - x * op.avg_hops), 0.05),
            num_hosts=num_hosts,
            horizon_s=horizon_s,
            seed=seed,
        )
        for x in x_adms
    ]
    batch = simulate_flows_batch(scns, engine=engine)
    agg_Bps = num_hosts * scns[0].nic_Bps
    return [
        float((s.sizes.sum() - rem.sum()) / horizon_s / agg_Bps)
        for s, rem in zip(scns, batch.remaining_bytes)
    ]


def run(ws_loads=(0.0, 0.02, 0.05, 0.08, 0.10), engine: str = "auto") -> dict:
    banner("Fig. 10 — aggregate throughput vs Websearch (latency) load")
    rows = []
    op, ex = OPERA_648_PT, EXPANDER_650_PT
    lat_cap = latency_capacity(op)
    x_adms = [min(x, lat_cap) for x in ws_loads]
    measured = _measured_bulk_frac(x_adms)
    flow_total = _flow_measured_total(x_adms, engine=engine)
    for x, x_adm, meas, ftot in zip(ws_loads, x_adms, measured, flow_total):
        # Opera: latency traffic at per-host load x occupies x*avg_hops
        # link-slots (the wire-byte tax); the remaining fabric slots carry
        # application-tagged shuffle over tax-free direct circuits.  The
        # *admission* limit on x itself is the transport-calibrated
        # latency_capacity; the *slot* cost is the structural x*L.
        slots = op.duty * op.u / op.d          # fabric slots per host-link
        bulk = max(0.0, 0.9 * (slots - x_adm * op.avg_hops))
        opera_total = x_adm + bulk
        # static networks: one taxed/oversubscribed pool for everything
        exp_total = latency_capacity(ex)
        clos_total = clos_capacity(3.0)
        rows.append(dict(ws_load=x, opera=opera_total, expander=exp_total,
                         clos=clos_total, opera_bulk_model=bulk,
                         opera_bulk_fluid=meas, opera_total_flowsim=ftot,
                         gain=opera_total / max(exp_total, clos_total)))
        print(f"  ws={x:4.2f}: opera {opera_total:.3f}  expander {exp_total:.3f}"
              f"  clos {clos_total:.3f}  -> {rows[-1]['gain']:.2f}x"
              f"   [bulk: model {bulk:.3f} | fluid {meas:.3f}]"
              f"   [total: model {opera_total:.3f} | flowsim {ftot:.3f}]")
    ok1 = check("~2-4x aggregate throughput at low latency load (paper 4x)",
                rows[0]["gain"] >= 2.0, f"{rows[0]['gain']:.2f}x")
    ok2 = check("~2x at 10% Websearch load (paper ~2x)",
                rows[-1]["gain"] >= 1.4, f"{rows[-1]['gain']:.2f}x")
    ratios = [
        r["opera_bulk_fluid"] / r["opera_bulk_model"]
        for r in rows
        if r["opera_bulk_model"] > 0.05
    ]
    ok3 = check(
        "fluid-measured bulk capacity tracks the eta-model (0.8-1.4x)",
        all(0.8 <= q <= 1.4 for q in ratios),
        f"ratios={[f'{q:.2f}' for q in ratios]}",
    )
    fratios = [r["opera_total_flowsim"] / r["opera"] for r in rows]
    ok4 = check(
        "flow-engine aggregate throughput tracks the model (0.75-1.25x)",
        all(0.75 <= q <= 1.25 for q in fratios),
        f"ratios={[f'{q:.2f}' for q in fratios]}",
    )
    return dict(rows=rows,
                checks=dict(low=ok1, ten_pct=ok2, fluid=ok3, flowsim=ok4))


if __name__ == "__main__":
    save("fig10_mixed", run())
