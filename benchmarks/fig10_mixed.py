"""Fig. 10: mixed Websearch(latency)+Shuffle(bulk) — aggregate throughput."""
from __future__ import annotations

from benchmarks.common import banner, check, save
from repro.netsim.capacity import (
    CLOS_648_PT,
    EXPANDER_650_PT,
    OPERA_648_PT,
    bulk_capacity_opera,
    clos_capacity,
    latency_capacity,
)


def run(ws_loads=(0.0, 0.02, 0.05, 0.08, 0.10)) -> dict:
    banner("Fig. 10 — aggregate throughput vs Websearch (latency) load")
    rows = []
    op, ex = OPERA_648_PT, EXPANDER_650_PT
    for x in ws_loads:
        # Opera: latency traffic at per-host load x occupies x*avg_hops
        # link-slots (the wire-byte tax); the remaining fabric slots carry
        # application-tagged shuffle over tax-free direct circuits.  The
        # *admission* limit on x itself is the transport-calibrated
        # latency_capacity; the *slot* cost is the structural x*L.
        lat_cap = latency_capacity(op)
        slots = op.duty * op.u / op.d          # fabric slots per host-link
        x_adm = min(x, lat_cap)
        bulk = max(0.0, 0.9 * (slots - x_adm * op.avg_hops))
        opera_total = x_adm + bulk
        # static networks: one taxed/oversubscribed pool for everything
        exp_total = latency_capacity(ex)
        clos_total = clos_capacity(3.0)
        rows.append(dict(ws_load=x, opera=opera_total, expander=exp_total,
                         clos=clos_total,
                         gain=opera_total / max(exp_total, clos_total)))
        print(f"  ws={x:4.2f}: opera {opera_total:.3f}  expander {exp_total:.3f}"
              f"  clos {clos_total:.3f}  -> {rows[-1]['gain']:.2f}x")
    ok1 = check("~2-4x aggregate throughput at low latency load (paper 4x)",
                rows[0]["gain"] >= 2.0, f"{rows[0]['gain']:.2f}x")
    ok2 = check("~2x at 10% Websearch load (paper ~2x)",
                rows[-1]["gain"] >= 1.4, f"{rows[-1]['gain']:.2f}x")
    return dict(rows=rows, checks=dict(low=ok1, ten_pct=ok2))


if __name__ == "__main__":
    save("fig10_mixed", run())
