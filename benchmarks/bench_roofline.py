"""§Roofline: per-(arch x shape x mesh) terms from the dry-run artifacts.

Reads results/dryrun/*.json (produced by `python -m repro.launch.dryrun`)
and prints the full baseline table + skip rows.  The dry-run itself is NOT
re-run here (512 fake devices must not leak into the bench process).
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

from benchmarks.common import banner, check, save
from repro.analysis.roofline import fmt_table
from repro.configs import SHAPES, get_config, list_archs

_RES = Path(__file__).resolve().parents[1] / "results"
# prefer the latest cost-model revision of the sweep
DRYRUN = next(
    (d for d in (_RES / "dryrun_v3", _RES / "dryrun_v2", _RES / "dryrun")
     if d.exists() and any(d.glob("*__pod.json"))),
    _RES / "dryrun",
)


def run() -> dict:
    banner("Roofline — baseline terms for every (arch x shape x mesh) cell")
    rows, missing = [], []
    skips = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape not in cfg.shapes:
                skips.append(dict(arch=arch, shape=shape,
                                  reason=cfg.skipped_shapes.get(shape, "n/a")))
                continue
            for mesh in ("pod", "multipod"):
                f = DRYRUN / f"{arch}__{shape}__{mesh}.json"
                if not f.exists():
                    missing.append(f.name)
                    continue
                rec = json.loads(f.read_text())
                rows.append(rec["roofline"])
    pod_rows = [r for r in rows if r["mesh"] == "pod"]
    print(fmt_table(pod_rows))
    print(f"\n  ({len(rows) - len(pod_rows)} multipod cells also compiled; "
          f"table shown single-pod per the assignment)")
    if skips:
        print("\n  skipped cells (sub-quadratic rule):")
        for s in skips:
            print(f"    {s['arch']:24s} {s['shape']:10s} — {s['reason'][:60]}")
    n_runnable = sum(len(get_config(a).shapes) for a in list_archs())
    ok1 = check(
        f"all {n_runnable} runnable single-pod cells present",
        len(pod_rows) == n_runnable, f"{len(pod_rows)}/{n_runnable}",
    )
    ok2 = check("all runnable multipod cells present",
                len(rows) - len(pod_rows) == n_runnable,
                f"{len(rows)-len(pod_rows)}/{n_runnable}")
    ok3 = check("40 total cells accounted for (runnable + skipped)",
                len(pod_rows) + len(skips) == 40)
    return dict(rows=rows, skips=skips, missing=missing,
                checks=dict(pod=ok1, multipod=ok2, total=ok3))


if __name__ == "__main__":
    save("bench_roofline", run())
