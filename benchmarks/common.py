"""Shared benchmark plumbing: result I/O + tiny ASCII plotting."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, Dict

RESULTS = Path(__file__).resolve().parents[1] / "results" / "benchmarks"


def save(name: str, payload: Dict) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{name}.json"
    p.write_text(json.dumps(payload, indent=1, default=float))
    return p


def banner(title: str):
    print("\n" + "=" * 78)
    print(f"== {title}")
    print("=" * 78)


def check(desc: str, ok: bool, detail: str = ""):
    mark = "PASS" if ok else "WARN"
    print(f"  [{mark}] {desc}" + (f" — {detail}" if detail else ""))
    return bool(ok)


def run_timed(fn: Callable[[], Dict], name: str) -> Dict:
    t0 = time.time()
    out = fn()
    out["_seconds"] = round(time.time() - t0, 2)
    save(name, out)
    return out


def ascii_curve(xs, ys, width=60, label=""):
    """One-line-per-point ascii plot for terminal-readable benchmarks."""
    if not ys:
        return
    lo, hi = min(ys), max(ys)
    rng = (hi - lo) or 1.0
    for x, y in zip(xs, ys):
        n = int((y - lo) / rng * width)
        print(f"  {x:>10} | {'#' * n}{' ' * (width - n)} {y:.4g} {label}")
