"""Quickstart: the paper's mechanism end-to-end in 60 seconds on CPU.

1. Build the 648-host Opera topology; show slices are expanders and every
   rack pair gets a direct circuit each cycle.
2. Run the two traffic classes through the fluid simulator.
3. Run the SAME schedule as a JAX collective: a rotor all-reduce syncing
   gradients of a tiny model (the TPU adaptation).
"""
import numpy as np

import jax
from repro import compat
import jax.numpy as jnp

from repro.configs.opera_paper import OPERA_648
from repro.core.expander import mean_max_path, spectral_gap
from repro.core.schedule import cycle_timing
from repro.core.topology import build_opera_topology
from repro.netsim.fluid import simulate_rotor_bulk
from repro.netsim.workloads import demand_all_to_all

print("== 1. Topology: expansion at every instant, direct circuits over time")
topo = build_opera_topology(108, 6, seed=0)
adj = topo.adjacency(0)
mean_h, max_h, disc = mean_max_path(adj)
print(f"   slice 0: mean path {mean_h:.2f}, max {max_h}, "
      f"spectral gap {spectral_gap(adj):.3f}, disconnected pairs {disc}")
ds = topo.direct_slice()
print(f"   every rack pair direct once/cycle: "
      f"{bool((ds[~np.eye(108, dtype=bool)] >= 0).all())}")
t = cycle_timing(OPERA_648)
print(f"   cycle {t.cycle_ms:.1f} ms, duty {100*t.duty_cycle:.1f}%, "
      f"bulk cutoff {t.bulk_cutoff_mb:.0f} MB  (paper: 10.7 ms / 98% / 15 MB)")

print("\n== 2. Bulk class: 100 KB shuffle rides tax-free direct circuits")
r = simulate_rotor_bulk(OPERA_648, demand_all_to_all(108, 6, 100e3),
                        vlb=False, max_cycles=40)
print(f"   99p FCT {r.fct_99_ms:.1f} ms (paper: 60 ms), "
      f"bandwidth tax {100*r.bandwidth_tax:.2f}%")

print("\n== 3. Same schedule as a JAX collective (rotor gradient sync)")
from repro.core import collectives as C  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

n = len(jax.devices())
mesh = compat.make_mesh((n, 1), ("data", "model"))
grads = jnp.arange(8.0 * n).reshape(n, 8)
rotor = jax.jit(compat.shard_map(
    lambda g: C.rotor_all_reduce(g, "data"),
    mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_vma=False,
))(grads)
want = jax.jit(compat.shard_map(
    lambda g: jax.lax.psum(g, "data"),
    mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_vma=False,
))(grads)
print(f"   rotor_all_reduce == psum: {bool(jnp.allclose(rotor, want))}")
print(f"   wire-byte ledger (N=16): {C.schedule_stats(16)}")
print("\nquickstart OK")
