"""End-to-end driver: train a ~100M-param model for a few hundred steps.

Uses the REAL smollm-360m architecture at trimmed depth/width so that a
~100M-parameter model trains in CPU-minutes, with the opera-dp trainer
(explicit rotor gradient sync + latency-class telemetry), checkpointing
every 50 steps, and a resume demonstration.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""
import argparse
import time

import jax
from repro import compat
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh, pctx_for_mesh
from repro.models import init_params
from repro.optim.adamw import AdamWConfig
from repro.train.checkpoint import Checkpointer
from repro.train.opera_dp import init_opera_dp_state, make_opera_dp_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    # ~100M params: smollm-360m trimmed to 6 layers / vocab 8192
    cfg = get_config("smollm-360m").replace(
        num_layers=6, vocab_size=8192, tie_embeddings=True
    )
    params = init_params(cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    mesh = make_host_mesh()
    pctx = pctx_for_mesh(mesh)
    opt = AdamWConfig(lr=8e-4, warmup_steps=30, total_steps=args.steps)
    step_fn = jax.jit(make_opera_dp_train_step(cfg, pctx, opt))
    state = init_opera_dp_state(params)
    src = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)
    ck = Checkpointer(args.ckpt, keep=2)

    print(f"model: {n/1e6:.1f}M params | floor {src.conditional_entropy():.3f}"
          f" nats | uniform {np.log(cfg.vocab_size):.3f} nats")
    t0, losses = time.time(), []
    with compat.set_mesh(mesh):
        for i in range(args.steps):
            state, m = step_fn(
                state, jax.tree.map(jnp.asarray, src.batch_at(i))
            )
            losses.append(float(m["loss"]))
            if i % 20 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {losses[-1]:.4f} "
                      f"gnorm {float(m['grad_norm']):.2f} "
                      f"({time.time()-t0:.0f}s)", flush=True)
            if (i + 1) % 50 == 0:
                ck.save(i + 1, state)
    ck.wait()
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"(uniform {np.log(cfg.vocab_size):.3f}, "
          f"floor {src.conditional_entropy():.3f})")
    assert last < first - 0.4, "training failed to learn"
    print("train_e2e OK")


if __name__ == "__main__":
    main()
