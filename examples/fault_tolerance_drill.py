"""Failure drill: worker dies mid-run -> detect -> shrink -> restore -> resume.

The control-plane loop of DESIGN.md §3.2 (Opera's hello-protocol analog):
heartbeats feed the FleetMonitor; on a missed-heartbeat failure the
controller forms a RestartPlan (shrunk data axis), restores the latest
elastic checkpoint, and resumes deterministically (data is step-indexed).

This is the *control-plane* half of the repo's failure story.  The
*data-plane* half — what the fabric itself does while a link, ToR, or
rotor switch is down — lives in `repro.netsim.faults`: the same
detect-lag/recover timeline drives per-slice capacity masks through
both batched engines (blackhole during the detection window, reroute
and retry after), and `benchmarks/fig11_faults.py` measures the
resulting throughput retention and FCT inflation dynamically (the
paper's Fig. 11).  See ROADMAP "Fault model (PR 4)".

    PYTHONPATH=src python examples/fault_tolerance_drill.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced_config
from repro.data.pipeline import SyntheticLM
from repro.models import init_params
from repro.models.parallel import single_device_ctx
from repro.optim.adamw import AdamWConfig
from repro.train.checkpoint import Checkpointer
from repro.train.health import FleetMonitor, HealthConfig, RestartPlan
from repro.train.trainer import init_train_state, make_train_step

cfg = reduced_config(get_config("yi-9b")).replace(vocab_size=128)
params = init_params(cfg, jax.random.key(0))
opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=40)
step_fn = jax.jit(make_train_step(cfg, single_device_ctx(), opt))
src = SyntheticLM(cfg.vocab_size, 32, 4, seed=0)

with tempfile.TemporaryDirectory() as d:
    ck = Checkpointer(d, keep=2)
    mon = FleetMonitor([f"worker{i}" for i in range(8)],
                       HealthConfig(timeout_steps=3))
    state = init_train_state(cfg, params)
    crashed = None
    for i in range(40):
        state, m = step_fn(state, jax.tree.map(jnp.asarray, src.batch_at(i)))
        for w in list(mon.workers):
            if w == "worker5" and i >= 12:
                continue  # worker5 stops heartbeating at step 12
            mon.heartbeat(w, i + 1, 1.0)
        if (i + 1) % 10 == 0:
            ck.save(i + 1, state, blocking=True)
            print(f"step {i+1:3d}: checkpoint saved, loss {float(m['loss']):.3f}")
        dead = mon.check(i + 1)["dead"]
        if dead:
            crashed = i + 1
            print(f"step {i+1:3d}: DETECTED failure of {dead} "
                  f"(missed {HealthConfig().timeout_steps} heartbeats)")
            break

    assert crashed is not None
    plan = RestartPlan.from_failure(mon, ck.latest_step(),
                                    devices_per_worker=4, model_axis=2)
    print(f"restart plan: survivors={len(plan.surviving_workers)}, "
          f"new mesh {plan.new_mesh_shape}, restore step {plan.restore_step}")
    state, start = ck.restore(state, step=plan.restore_step)
    for i in range(start, 40):
        state, m = step_fn(state, jax.tree.map(jnp.asarray, src.batch_at(i)))
    print(f"resumed {start} -> 40, final loss {float(m['loss']):.3f}")
    assert np.isfinite(float(m["loss"]))
print("fault_tolerance_drill OK")
