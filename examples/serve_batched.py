"""Serve a small model with batched requests (continuous batching).

Three architectures from three families share the one engine: dense KV
cache, Mamba recurrent state, and Griffin's hybrid window+LRU state.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced_config
from repro.models import init_params
from repro.models.parallel import single_device_ctx
from repro.serve.engine import Request, ServeEngine

rng = np.random.default_rng(0)

for arch in ("smollm-360m", "falcon-mamba-7b", "recurrentgemma-2b"):
    cfg = reduced_config(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, single_device_ctx(), slots=4, max_seq=48)
    t0 = time.time()
    n_req = 8
    for rid in range(n_req):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, rng.integers(4, 12))
            .astype(np.int32),
            max_new_tokens=8,
        ))
    done = eng.run_to_completion(max_ticks=200)
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    assert len(done) == n_req
    print(f"{arch:24s} ({cfg.family:6s}): {n_req} reqs, {toks} tokens in "
          f"{dt:.1f}s — sample output {done[0].out_tokens}")
print("serve_batched OK")
