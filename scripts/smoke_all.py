"""Dev driver: run reduced-config fwd/train/prefill/decode for all archs,
then a tiny netsim sweep through the batched JAX fluid engine."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import list_archs, get_config
from repro.configs.base import reduced_config
from repro.models import (
    forward_decode,
    forward_prefill,
    forward_train,
    init_params,
    loss_fn,
)
from repro.models.kvcache import init_cache
from repro.models.parallel import single_device_ctx

only = sys.argv[1:] if len(sys.argv) > 1 else None
pctx = single_device_ctx()
rng = np.random.default_rng(0)
B, S = 2, 16

for arch in list_archs():
    if only and arch not in only:
        continue
    cfg = reduced_config(get_config(arch))
    key = jax.random.key(0)
    params = init_params(cfg, key)
    nparams = sum(x.size for x in jax.tree.leaves(params))
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["encoder_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16
        )
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_image_tokens, cfg.d_model)), jnp.bfloat16
        )

    # train fwd + grad
    (total, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg, pctx), has_aux=True
    )(params)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert jnp.isfinite(total), f"{arch}: non-finite loss"
    assert jnp.isfinite(gnorm), f"{arch}: non-finite grads"

    # prefill + decode
    logits_p, caches = forward_prefill(params, batch, cfg, pctx)
    assert logits_p.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits_p.astype(jnp.float32)).all()
    tok = jnp.argmax(logits_p, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    logits_d, caches2 = forward_decode(params, tok, pos, caches, cfg, pctx)
    assert logits_d.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits_d.astype(jnp.float32)).all()
    print(
        f"ok {arch:24s} params={nparams:>9,} loss={float(metrics['loss']):.3f} "
        f"gnorm={float(gnorm):.3f}"
    )
print("ALL ARCH SMOKE PASSED")

# netsim: one design point's (workload x load) grid in a single vmapped call
from repro.netsim.sweep import DesignPoint, SweepSpec, run_sweep

rows = run_sweep(
    SweepSpec(
        designs=(DesignPoint(k=4, num_racks=8),),
        workloads=("shuffle", "permutation"),
        loads=(0.2,),
        seeds=(0,),
        max_cycles=60,
    )
)
assert all(r["finished_frac"] >= 0.999 for r in rows), rows
assert all(r["bandwidth_tax"] >= -1e-6 for r in rows), rows
print(f"ok netsim sweep: {len(rows)} scenarios, "
      f"fct99={rows[0]['fct_99_ms']:.2f} ms")
print("SWEEP SMOKE PASSED")

# flow-level engine: tiny (network x load) grid in one vmapped scan
from repro.netsim.flows_jax import simulate_grid

frows = simulate_grid(
    ("opera", "expander"),
    ("websearch",),
    (0.05,),
    seeds=(0,),
    num_hosts=16,
    horizon_s=0.1,
    dt_s=5e-4,
    tail_s=0.05,
)
assert len(frows) == 2, frows
assert all(np.isfinite(r["backlog_frac"]) for r in frows), frows
assert all(0.0 <= r["finished_frac"] <= 1.0 for r in frows), frows
print(f"ok flow engine: {len(frows)} scenarios, "
      f"finished={frows[0]['finished_frac']:.3f}")
print("FLOW SMOKE PASSED")

# tiled streaming flow engine: the same grid through the chunked
# windowed path (deliberately tiny tiles so retirement + growth run)
# must agree with the dense rows it just produced
trows = simulate_grid(
    ("opera", "expander"),
    ("websearch",),
    (0.05,),
    seeds=(0,),
    num_hosts=16,
    horizon_s=0.1,
    dt_s=5e-4,
    tail_s=0.05,
    engine="tiled",
    tile_size=32,
    window_tiles=1,
    chunk_steps=16,
)
assert len(trows) == len(frows), trows
for d, t in zip(frows, trows):
    assert d["network"] == t["network"]
    assert d["finished_frac"] == t["finished_frac"], (d, t)
    assert d["admitted"] == t["admitted"], (d, t)
    assert abs(d["backlog_frac"] - t["backlog_frac"]) < 1e-5, (d, t)
print(f"ok tiled flow engine: {len(trows)} scenarios match dense")
print("TILED FLOW SMOKE PASSED")

# fault injection: the empty schedule must dispatch to the failure-free
# program bit-for-bit, and a seeded mixed draw (links + one switch, with
# a detection lag and mid-run recovery) must blackhole in-flight bytes
# yet still drain the demand — the graceful-degradation contract the
# dynamic Fig. 11 measures at scale
from repro.core.topology import build_opera_topology
from repro.netsim.faults import FailureSchedule
from repro.netsim.fluid_jax import simulate_rotor_bulk_batch

ftopo = build_opera_topology(8, 2, seed=0)
fcfg = DesignPoint(k=4, num_racks=8).to_config()
fdem = np.full((8, 8), 2e6)
np.fill_diagonal(fdem, 0.0)
clean = simulate_rotor_bulk_batch(fcfg, fdem[None], topo=ftopo, max_cycles=40)
empty = simulate_rotor_bulk_batch(
    fcfg, fdem[None], topo=ftopo, max_cycles=40,
    faults=[FailureSchedule.empty(ftopo)])
assert np.array_equal(clean.finished_frac, empty.finished_frac), \
    "FailureSchedule.empty() is not bit-identical to the clean engine"
sched = FailureSchedule.draw(ftopo, seed=3, link_frac=0.15, switch_count=1,
                             onset_step=4, detect_lag=3, recover_step=60)
faulted = simulate_rotor_bulk_batch(
    fcfg, fdem[None], topo=ftopo, max_cycles=40, faults=[sched])
assert faulted.blackholed_bytes is not None
assert faulted.blackholed_bytes[0] > 0.0, "detection lag blackholed nothing"
assert faulted.finished_frac[0, -1] >= 0.999, \
    f"faulted run failed to drain: {faulted.finished_frac[0, -1]:.4f}"
print(f"ok faults: empty bit-identical, "
      f"blackholed={faulted.blackholed_bytes[0]:.0f} B, "
      f"finished={faulted.finished_frac[0, -1]:.3f}")
print("FAULT SMOKE PASSED")

# permutation-sparse engine: the gather/scatter backend (engine="sparse",
# kernels/rotor_slice over matching_index_tensor()) must reproduce the
# dense scan engine on the very same runs — clean and faulted alike
sp_clean = simulate_rotor_bulk_batch(
    fcfg, fdem[None], topo=ftopo, max_cycles=40, engine="sparse")
assert np.allclose(np.asarray(clean.finished_frac),
                   np.asarray(sp_clean.finished_frac), atol=1e-5), \
    "sparse engine diverges from dense on the clean run"
sp_faulted = simulate_rotor_bulk_batch(
    fcfg, fdem[None], topo=ftopo, max_cycles=40, faults=[sched],
    engine="sparse")
assert np.allclose(np.asarray(faulted.finished_frac),
                   np.asarray(sp_faulted.finished_frac), atol=1e-5), \
    "sparse engine diverges from dense under faults"
bh_gap = abs(float(sp_faulted.blackholed_bytes[0]
                   - faulted.blackholed_bytes[0])) / float(fdem.sum())
assert bh_gap < 1e-6, f"blackholed-byte drift {bh_gap:.2e}"
print(f"ok sparse engine: clean+faulted parity, "
      f"blackholed drift={bh_gap:.1e}")
print("SPARSE SMOKE PASSED")

# static analysis: Opera invariants on a small App-B point, the whole-tree
# AST policy rules, and the jaxpr engine rules (f64/callback/recompile)
import os

from repro.staticcheck.cli import run_ast, run_invariants, run_jaxpr
from repro.staticcheck.findings import Report

repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
report = Report()
run_invariants(report, [(8, 16, 1)], gap_frac=0.3)
run_ast(report, repo_root, None)
run_jaxpr(report)
os.makedirs(os.path.join(repo_root, "results"), exist_ok=True)
report.to_json(os.path.join(repo_root, "results", "staticcheck.json"))
assert report.ok, "\n".join(str(f) for f in report.findings)
print(f"ok staticcheck: {len(report.checks_run)} checks, "
      f"{len(report.findings)} findings")
print("STATICCHECK SMOKE PASSED")
