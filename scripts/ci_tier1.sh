#!/usr/bin/env bash
# Tier-1 gate: full test suite + architecture/netsim smoke + static analysis.
# Run from the repo root:  bash scripts/ci_tier1.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src

python -m pytest -x -q
python scripts/smoke_all.py
# static analysis over the whole tree (invariants + AST + jaxpr rules);
# fails on new violations and emits the machine-readable report.
python -m repro.staticcheck --json results/staticcheck.json
# dynamic Fig. 11 fault sweep on the paper design point (--fast mode);
# benchmarks/ is a repo-root package, so the root joins PYTHONPATH here.
PYTHONPATH=src:. python benchmarks/fig11_faults.py --fast
# engine parity gates (no timing): sparse-vs-dense rotor runs at the
# small Appendix-B points, and tiled-vs-dense flow runs (bitwise FCT
# histograms, streamed percentiles within one bin); fails on any drift.
PYTHONPATH=src:. python -m benchmarks.perf_track --fast
echo "CI TIER-1 GREEN"
