#!/usr/bin/env bash
# Tier-1 gate: full test suite + architecture/netsim smoke.
# Run from the repo root:  bash scripts/ci_tier1.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src

python -m pytest -x -q
python scripts/smoke_all.py
echo "CI TIER-1 GREEN"
