"""Assemble the §Roofline table and §Perf log into EXPERIMENTS.md from the
final dry-run artifacts (results/dryrun_v3)."""
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
V3 = ROOT / "results" / "dryrun_v3"

import sys
sys.path.insert(0, str(ROOT / "src"))
from repro.analysis.roofline import fmt_table  # noqa: E402
from repro.configs import SHAPES, get_config, list_archs  # noqa: E402


def roofline_md() -> str:
    rows = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape not in cfg.shapes:
                continue
            f = V3 / f"{arch}__{shape}__pod.json"
            if f.exists():
                rows.append(json.loads(f.read_text())["roofline"])
    lines = ["```", fmt_table(rows), "```", "",
             "Skipped cells (assignment's sub-quadratic rule): "]
    skips = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape not in cfg.shapes:
                skips.append(f"{arch}×{shape}")
    lines.append(", ".join(skips) + ".")
    return "\n".join(lines)


def _terms(name):
    r = json.loads((V3 / f"{name}.json").read_text())["roofline"]
    return (r["compute_s"], r["memory_s"], r["collective_s"],
            r["useful_ratio"], r["mfu_at_floor"], r["dominant"])


def perf_md() -> str:
    def fmt(name):
        c, m, k, u, f, d = _terms(name)
        return f"compute {c:.3f}s / memory {m:.3f}s / coll {k:.4f}s (dom {d}, useful {u:.2f}, MFU@floor {f:.3f})"

    out = []
    out.append("**Iteration 0 (measurement substrate).** Three rounds of "
               "hypothesis-driven *cost-model* fixes preceded the code "
               "hillclimb, each exposed by a refuted prediction: (0a) XLA "
               "cost_analysis counts scan bodies once → loop-aware trip "
               "multiplication (validated vs unrolled HLO); (0b) scan "
               "accumulators (dynamic-update-slice fusions) were charged "
               "full-buffer×trip → in-place slice accounting (−17% memory "
               "term fleet-wide); (0c) per-layer reads of scan-stacked "
               "weights were charged the full stack → sliced-parameter "
               "discount. A refuted hypothesis is as informative as a "
               "confirmed one — here they were bugs in the ruler, not the "
               "system.\n")

    A0 = fmt("smollm-360m__train_4k__pod")
    A1 = fmt("smollm-360m__train_4k__pod__iterA1")
    A2 = fmt("smollm-360m__train_4k__pod__iterA2")
    A3 = fmt("smollm-360m__train_4k__pod__iterA3")
    out.append(f"""### Cell A — smollm-360m × train_4k (worst useful ratio)

| iter | hypothesis | change | result | verdict |
|---|---|---|---|---|
| A0 | — | baseline (FSDP×TP) | {A0} | 15 Q / 5 KV heads don't divide tp=16 → reshape breaks head-dim sharding → attention replicated ×16 over `model` (napkin: (0.75+1.6)e15 × 16 ≈ measured HLO FLOPs ✓) |
| A1 | repurpose `model` as data parallelism (batch 256 = 16×16 exactly); replication disappears | `--layout dp_only` | {A1} | **CONFIRMED** — memory ÷15, collective ÷60, MFU@floor ×15 |
| A2 | remaining memory is logits CE materialization | `+ --loss-chunk 16384` | {A2} | **REFUTED** — bytes unchanged; profile shows chunked-attention accumulators + norm traffic dominate, logits are minor at vocab 49k/dev |
| A3 | at 360M the remat recompute isn't worth it: saving activations cuts the backward's recompute passes | `--layout dp_only --remat none` | {A3} | **REFUTED for the floor** — compute −21% (≈ the −25% napkin) and useful ratio ↑0.46→0.58, but saved-activation traffic exceeds the recompute traffic it displaces: memory +22%. Keep A1. |
""")

    B0 = fmt("falcon-mamba-7b__decode_32k__pod")
    B1 = fmt("falcon-mamba-7b__decode_32k__pod__iterB1")
    B2 = fmt("falcon-mamba-7b__decode_32k__pod__iterB2")
    out.append(f"""### Cell B — falcon-mamba-7b × decode_32k (most collective-bound)

| iter | hypothesis | change | result | verdict |
|---|---|---|---|---|
| B0 | — | baseline | {B0} | collectives = 1.7 GB/dev of ALL-GATHERS = exactly the FSDP weight gathering (7.3e9×4×(15/16)/16 ≈ 1.7 GB ✓) — decode re-gathers weights every token |
| B1 | keep weights resident TP-sharded (serving layout): gathers vanish | `--layout tp_only` | {B1} | **CONFIRMED for collectives** (÷57) but fp32 weight *reads* (1.8 GB/dev/token) now dominate memory |
| B2 | store weights bf16 (production serving): halve resident reads, kill the fp32→bf16 convert traffic | `+ --param-dtype bfloat16` | {B2} | **REFUTED** — the converts vanish but bf16 params re-upcast at fp32 consumers (gates, A_log math), adding back what was saved. **B1 stands: step floor 0.0342 → 0.0116 s (2.9×)**, now memory-bound on resident weight reads — the correct regime for decode. |
""")

    C0 = fmt("qwen3-moe-30b-a3b__train_4k__pod")
    C1 = fmt("qwen3-moe-30b-a3b__train_4k__pod__iterC1") if (V3 / "qwen3-moe-30b-a3b__train_4k__pod__iterC1.json").exists() else "n/a"
    C2 = fmt("qwen3-moe-30b-a3b__train_4k__pod__iterC2")
    C3 = fmt("qwen3-moe-30b-a3b__train_4k__pod__iterC3")
    C4 = fmt("qwen3-moe-30b-a3b__train_4k__pod__iterC4")
    out.append(f"""### Cell C — qwen3-moe-30b-a3b × train_4k (technique-representative)

| iter | hypothesis | change | result | verdict |
|---|---|---|---|---|
| C0 | — | baseline (rotor A2A dispatch) | {C0} | memory-dominant; profile: fp32 residual-stream passes in the rematted backward + MoE dispatch buffers |
| C1 | logits CE (B,S,V) materialization drives memory | `--loss-chunk 19456` | {C1 if isinstance(C1, str) else C1} | **REFUTED** — memory ~flat, compute +45% (chunk recompute); logits ≈ 2.5 GB/dev ≪ 35 TB of residual traffic |
| C2 | fp32 norm materialization doubles residual traffic; keep reductions fp32, normalize in bf16 | `--norm-upcast 0` | {C2} | **REFUTED** — no measurable change: the fp32 traffic originates in autodiff of the fp32 reductions + the saved bf16 carry chain, not in the normalize materialization choice. |
| C3 | sequence-parallel activations shrink per-device residual/saved tensors ×16 | `--act-sharding sp` | {C3} | **REFUTED** — chunked-attention reshapes break seq sharding → gathers+replication: memory ×2, collective ×9. SP needs a seq-aware attention partition, not a constraint bolt-on |
| C4 | A/B the paper technique itself: rotor A2A vs native all-to-all move the same bytes (the direct one-hop schedule is tax-free either way) | `--moe-dispatch xla` | {C4} | **CONFIRMED, beyond-paper** — collective term IDENTICAL (8.2108 s both: zero-tax parity exactly as the schedule theory predicts) while the fused native a2a avoids ~17% of buffer-staging memory traffic (floor 25.52 → 21.15 s). On a real rotor fabric the ppermute schedule is the *only* option; on a fixed torus ICI, prefer the fused op and keep the rotor schedule for the fabrics that need it. |
""")
    out.append("""### Outcome summary (step-time floor = max roofline term)

| cell | baseline | best | gain | stopping rule |
|---|---|---|---|---|
| smollm-360m × train_4k | 55.33 s (memory) | **3.58 s** (A1) | **15.5×** | A2 +0.4%, A3 −22% → stopped |
| falcon-mamba-7b × decode_32k | 0.0342 s (collective) | **0.0116 s** (B1) | **2.9×** | B2 regressed → stopped |
| qwen3-moe-30b-a3b × train_4k | 25.52 s (memory) | **21.15 s** (C4) | **1.21×** | C1/C2 ≈0%, C3 regressed → stopped |

Paper-faithful baseline and beyond-paper optimized variants are SEPARATE
artifacts (`__pod.json` vs `__pod__iter*.json`) per the assignment: the
baselines carry the rotor schedules exactly as Opera prescribes; the
optimized variants change sharding layout / dispatch fusion — levers the
paper doesn't discuss.""")
    return "\n".join(out)


def main():
    md = (ROOT / "EXPERIMENTS.md").read_text()
    md = md.replace("<!-- ROOFLINE_TABLE -->", roofline_md())
    md = md.replace("<!-- PERF_LOG -->", perf_md())
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md finalized")


if __name__ == "__main__":
    main()
